//! Launcher: `namelist.input` + `adios2.xml` → configured forecast run.
//!
//! This is the `wrf.exe` surface of the repo: everything the paper tunes
//! (io_form, aggregator count, compression codec, burst-buffer target,
//! node count) is configured here exactly the way their WRF patch does it
//! — namelist first, XML for the ADIOS2-specific engine details.  The
//! engine knobs flow through the planning layer (DESIGN.md §12): the
//! namelist's `adios2_*` entries become a typed [`IoIntent`], every knob
//! accepts the `'auto'` sentinel (cost-model-chosen value), and the
//! resolved [`IoPlan`] is the only thing the engines see.  Inspect the
//! decisions without running: `stormio plan <namelist.input>` prints the
//! decision table plus predicted virtual costs (`t_write`,
//! `time_to_first_analysis`) — the same provenance every run and bench
//! report carries.
//!
//! Recognized namelist entries (beyond standard WRF ones):
//!
//! ```text
//! &time_control
//!   history_interval       = 30,       ! simulated minutes per frame
//!   frames                 = 4,        ! history frames to write
//!   io_form_history        = 22,       ! 2 | 11 | 102 | 22 | 901(quilt)
//!   adios2_xml             = 'adios2.xml',
//!   adios2_num_aggregators = 1,        ! per node, or 'auto'
//!   adios2_compression     = 'lz4',    ! none|blosclz|lz4|zlib|zstd|auto
//!   adios2_target          = 'pfs',    ! pfs | bb | object | auto
//!   adios2_drain           = .false.,
//!   adios2_ensemble_writers = 1,       ! concurrent runs sharing the store
//!   adios2_sst_data_plane  = 'lanes',  ! lanes | funnel | auto (SST)
//!   adios2_sst_address     = 'h:p,h:p',! SST consumer list (fan-out)
//!   adios2_sst_broker      = .false.,  ! rank-0 mid-stream admission broker
//!   adios2_sst_hello_timeout = 30,     ! lane handshake bound [s]
//!   adios2_sst_max_lanes   = 65536,    ! lane-count sanity cap
//!   adios2_relay_fanout    = 'auto',   ! relay-tree branching; 0 = direct
//!   adios2_live_publish    = .false.,  ! per-step md.idx for followers
//!   frames_per_outfile     = 1,        ! 0 = all frames in one BP file
//!   nio_tasks              = 2,        ! quilt servers (io_form=901)
//! /
//! &domains
//!   e_we = 192, e_sn = 192, e_vert = 4,
//!   steps_per_history = 4,             ! demo-scale step count per frame
//! /
//! &stormio                              ! testbed extension group
//!   ranks = 4, ranks_per_node = 2,
//!   nodes = 2,                          ! virtual testbed nodes
//!   out_dir = 'run_out', seed = 11,
//!   volume_scale = 1.0,                 ! bytes → CONUS-scale factor
//! /
//! ```

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::adios::{Adios, EngineKind};
use crate::io::adios2::Adios2Backend;
use crate::io::api::HistoryBackend;
use crate::io::pnetcdf::PnetCdfBackend;
use crate::io::quilt::QuiltBackend;
use crate::io::serial_nc::SerialNcBackend;
use crate::io::split_nc::SplitNcBackend;
use crate::metrics::Table;
use crate::model::{ForecastConfig, ForecastDriver, RunSummary};
use crate::namelist::Namelist;
use crate::plan::{FeedbackController, IoIntent, IoPlan, PlanChange, Planner, WorkloadShape};
use crate::runtime::{Manifest, ModelStep, XlaRuntime};
use crate::sim::{CostModel, HardwareSpec};
use crate::{Error, Result};

/// Fully-resolved run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub forecast: ForecastConfig,
    pub io_form: i64,
    pub nio_tasks: usize,
    pub adios_xml: Option<String>,
    /// Typed engine-knob intent parsed from the `adios2_*` namelist
    /// entries ([`IoIntent::from_time_control`] — the only string parser
    /// for those keys).  Resolved into an [`IoPlan`] by
    /// [`RunConfig::resolve_plan`].
    pub intent: IoIntent,
    pub out_dir: PathBuf,
    pub nodes: usize,
    pub volume_scale: f64,
}

impl RunConfig {
    pub fn from_namelist(nl: &Namelist, base_dir: &std::path::Path) -> Result<RunConfig> {
        let tc = nl
            .group("time_control")
            .ok_or_else(|| Error::config("namelist missing &time_control"))?;
        let dom = nl
            .group("domains")
            .ok_or_else(|| Error::config("namelist missing &domains"))?;
        let st = nl.group("stormio");

        let get = |g: &crate::namelist::Group, k: &str, d: i64| g.get_i64(k).unwrap_or(d);
        let ranks = st.map(|g| get(g, "ranks", 4)).unwrap_or(4) as usize;
        let rpn = st.map(|g| get(g, "ranks_per_node", 2)).unwrap_or(2) as usize;
        let nodes = st
            .map(|g| get(g, "nodes", (ranks / rpn.max(1)).max(1) as i64))
            .unwrap_or((ranks / rpn.max(1)).max(1) as i64) as usize;
        let out_dir = st
            .and_then(|g| g.get_str("out_dir"))
            .unwrap_or("run_out")
            .to_string();
        let forecast = ForecastConfig {
            ny: get(dom, "e_sn", 192) as usize,
            nx: get(dom, "e_we", 192) as usize,
            nz: get(dom, "e_vert", 4) as usize,
            ranks,
            ranks_per_node: rpn,
            steps_per_interval: get(dom, "steps_per_history", 2) as usize,
            frames: get(tc, "frames", 2) as usize,
            write_t0: tc.get_bool("write_t0").unwrap_or(true),
            io_ranks: if get(tc, "io_form_history", 22) == 901 {
                get(tc, "nio_tasks", 1).max(1) as usize
            } else {
                0
            },
            halo: 2,
            seed: st.map(|g| get(g, "seed", 11)).unwrap_or(11) as u64,
            interval_minutes: get(tc, "history_interval", 30) as usize,
        };
        Ok(RunConfig {
            forecast,
            io_form: get(tc, "io_form_history", 22),
            nio_tasks: get(tc, "nio_tasks", 0) as usize,
            adios_xml: tc.get_str("adios2_xml").map(|s| s.to_string()),
            intent: IoIntent::from_time_control(tc)?,
            out_dir: base_dir.join(out_dir),
            nodes,
            volume_scale: st
                .and_then(|g| g.get_f64("volume_scale"))
                .unwrap_or(1.0),
        })
    }

    /// Virtual testbed for this run.
    pub fn hardware(&self) -> HardwareSpec {
        let mut hw = HardwareSpec::paper_testbed(self.nodes.max(1));
        hw.ranks_per_node = self.forecast.ranks_per_node;
        hw.volume_scale = self.volume_scale;
        hw
    }

    /// The workload shape the planner scores against: this grid's history
    /// frame, scaled to virtual (CONUS-equivalent) bytes.
    pub fn shape(&self) -> WorkloadShape {
        let wl = crate::workload::Workload::for_grid(
            self.forecast.ny,
            self.forecast.nx,
            self.forecast.nz,
        );
        WorkloadShape::from_physical(wl.frame_bytes(), self.volume_scale)
    }

    /// Load the ADIOS2 context (XML engine details only — the namelist
    /// knobs live in [`RunConfig::intent`] and meet the XML in
    /// [`RunConfig::resolve_plan`]).
    pub fn adios(&self, base_dir: &std::path::Path) -> Result<Adios> {
        let mut adios = match &self.adios_xml {
            Some(p) => Adios::from_xml_file(base_dir.join(p))?,
            None => Adios::default(),
        };
        adios.declare_io("wrf_history");
        Ok(adios)
    }

    /// The planner for this run's testbed and workload shape.
    pub fn planner(&self) -> Planner {
        Planner::new(CostModel::new(self.hardware()), self.shape())
    }

    /// The merged (namelist-over-XML) knob intent of the run's ADIOS2 io
    /// — the `'auto'` sentinels survive the merge, which is what the
    /// closed replan loop re-resolves against.
    pub fn merged_intent(&self, adios: &Adios) -> Result<IoIntent> {
        let io = adios
            .config
            .io("wrf_history")
            .ok_or_else(|| Error::config("io `wrf_history` not declared"))?;
        self.intent.merge_io_config(io)
    }

    /// Resolve the run's [`IoPlan`]: namelist intent over XML parameters,
    /// `'auto'` knobs decided by the cost model (the paper's §IV
    /// precedence, now through one typed path).
    pub fn resolve_plan(&self, adios: &Adios) -> Result<IoPlan> {
        let io = adios
            .config
            .io("wrf_history")
            .ok_or_else(|| Error::config("io `wrf_history` not declared"))?;
        let intent = self.intent.merge_io_config(io)?;
        self.planner().plan(io.engine.clone(), &intent)
    }

    /// Construct one rank's history backend from the resolved plan.
    pub fn make_backend(&self, plan: &IoPlan) -> Result<Box<dyn HistoryBackend>> {
        let cost = CostModel::new(self.hardware());
        let pfs = self.out_dir.join("pfs");
        let bb = self.out_dir.join("bb");
        Ok(match self.io_form {
            2 => Box::new(SerialNcBackend::new(pfs, cost)),
            11 => Box::new(PnetCdfBackend::new(pfs, cost)),
            102 => Box::new(SplitNcBackend::new(pfs, cost)),
            22 => Box::new(Adios2Backend::from_plan(plan.clone(), pfs, bb, cost)?),
            901 => Box::new(QuiltBackend::new(pfs, cost, self.nio_tasks.max(1))),
            other => {
                return Err(Error::config(format!(
                    "unsupported io_form_history {other} (2|11|102|22|901)"
                )))
            }
        })
    }

    /// Construct one rank's ADIOS2 backend with the replan loop closed
    /// (`adios2_adaptive_replan`, DESIGN.md §17): every rank carries its
    /// own controller built from the same planner/intent/plan — the
    /// per-frame knob broadcast requires all ranks to participate — and
    /// rank 0's accepted changes land in `sink` at finish.
    pub fn make_adaptive_backend(
        &self,
        plan: &IoPlan,
        intent: &IoIntent,
        sink: Arc<Mutex<Vec<PlanChange>>>,
    ) -> Result<Box<dyn HistoryBackend>> {
        let cost = CostModel::new(self.hardware());
        let ctl = FeedbackController::new(self.planner(), intent.clone(), plan.clone());
        Ok(Box::new(
            Adios2Backend::from_plan(
                plan.clone(),
                self.out_dir.join("pfs"),
                self.out_dir.join("bb"),
                cost,
            )?
            .with_feedback(ctl)
            .with_changes_sink(sink),
        ))
    }
}

/// Run a forecast from a namelist file; prints the WRF-style report.
pub fn run_from_namelist(path: &std::path::Path, artifacts: &std::path::Path) -> Result<RunSummary> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("cannot read {}: {e}", path.display())))?;
    let nl = Namelist::parse(&text)?;
    let base = path.parent().unwrap_or(std::path::Path::new("."));
    let cfg = RunConfig::from_namelist(&nl, base)?;

    let rt = XlaRuntime::new()?;
    let man = Manifest::load(artifacts)?;
    let driver = ForecastDriver::new(cfg.forecast.clone())?;
    let (nyp, nxp) = driver.decomp.patch();
    let step = Arc::new(ModelStep::load(&rt, &man, nyp, nxp)?);
    let adios = cfg.adios(base)?;
    let plan = if cfg.io_form == 22 {
        let plan = cfg.resolve_plan(&adios)?;
        println!("{}", plan.summary_line());
        plan
    } else {
        // Non-ADIOS io_forms never consult the plan; a trivial null plan
        // keeps the backend constructor uniform.
        cfg.planner().plan(EngineKind::Null, &IoIntent::default())?
    };

    // Closed-loop adaptive re-planning (`adios2_adaptive_replan`,
    // DESIGN.md §17): only meaningful for the ADIOS2 backend.
    let adaptive_intent = if cfg.io_form == 22 {
        let merged = cfg.merged_intent(&adios)?;
        merged.adaptive.unwrap_or(false).then_some(merged)
    } else {
        None
    };
    let replans: Arc<Mutex<Vec<PlanChange>>> = Arc::new(Mutex::new(Vec::new()));

    let summary = driver.run(step, |_rank| {
        match &adaptive_intent {
            Some(intent) => cfg.make_adaptive_backend(&plan, intent, replans.clone()),
            None => cfg.make_backend(&plan),
        }
        .expect("backend construction failed")
    })?;
    print_summary(&cfg, &summary);
    for c in replans.lock().expect("plan-changes sink poisoned").iter() {
        println!("{}", c.summary());
    }
    Ok(summary)
}

/// Resolve and print the run's I/O plan without running it (the
/// `stormio plan` dry-run): decision table, provenance, and predicted
/// virtual costs.  Needs no AOT artifacts.
///
/// With `measure` (the `--measure` flag), the planner's codec knobs are
/// resolved against [`crate::plan::CodecProfile::measured`] — per-codec
/// compress throughput and ratio microbenchmarked **on this host** with a
/// WRF-like smooth field — instead of the paper-testbed defaults, and the
/// measured table is printed above the decision table.  Without the flag
/// the output is byte-identical to previous releases (CI golden-diffs
/// it).
///
/// `--measure-out FILE` additionally caches the measured profile as JSON
/// (implies `--measure`); `--measure-in FILE` reuses a cached profile
/// instead of re-running the microbenchmark, so a fleet of plan
/// invocations on one host pays for the measurement once.
pub fn plan_from_namelist(
    path: &std::path::Path,
    measure: bool,
    measure_out: Option<&std::path::Path>,
    measure_in: Option<&std::path::Path>,
) -> Result<IoPlan> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("cannot read {}: {e}", path.display())))?;
    let nl = Namelist::parse(&text)?;
    let base = path.parent().unwrap_or(std::path::Path::new("."));
    let cfg = RunConfig::from_namelist(&nl, base)?;
    let adios = cfg.adios(base)?;
    let io = adios
        .config
        .io("wrf_history")
        .ok_or_else(|| Error::config("io `wrf_history` not declared"))?;
    let intent = cfg.intent.merge_io_config(io)?;
    let mut planner = cfg.planner();
    let profile = if let Some(p) = measure_in {
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::config(format!("cannot read {}: {e}", p.display())))?;
        Some((
            crate::plan::CodecProfile::from_json(&text)?,
            format!("cached codec profile ({})", p.display()),
        ))
    } else if measure || measure_out.is_some() {
        // A smooth θ-like surface, the compressibility regime WRF
        // history frames live in (§V-D): 1 MiB is enough for stable
        // per-codec throughput without a noticeable pause.
        let sample: Vec<f32> =
            (0..(1 << 18)).map(|i| 280.0 + (i as f32 * 0.01).sin()).collect();
        let profile =
            crate::plan::CodecProfile::measured(crate::util::f32_slice_as_bytes(&sample))?;
        Some((
            profile,
            "measured codec throughput (this host, 1 MiB smooth field)".to_string(),
        ))
    } else {
        None
    };
    if let Some((profile, title)) = profile {
        if let Some(p) = measure_out {
            std::fs::write(p, profile.to_json())
                .map_err(|e| Error::config(format!("cannot write {}: {e}", p.display())))?;
            println!("codec profile cached to {}", p.display());
        }
        let mut t = Table::new(&title, &["codec", "compress", "ratio"]);
        for (codec, thr) in profile.entries() {
            t.row(&[
                format!("{codec:?}").to_lowercase(),
                format!("{:.2} GB/s", thr.compress_bps / 1e9),
                format!("{:.2}x", thr.ratio),
            ]);
        }
        println!("{}", t.render());
        planner = planner.with_codec_profile(profile);
    }
    let plan = planner.plan(io.engine.clone(), &intent)?;
    println!(
        "stormio plan — {} nodes x {} ranks/node, io_form {}",
        cfg.nodes, cfg.forecast.ranks_per_node, cfg.io_form
    );
    if cfg.io_form != 22 {
        println!("note: io_form {} does not use the ADIOS2 engine plan", cfg.io_form);
    }
    print!("{}", plan.render("wrf_history"));
    Ok(plan)
}

/// Run the paper's full in-situ pipeline from a namelist: one forecast
/// producer streaming over the SST fan-out data plane to **three
/// concurrent consumers** — in-situ analysis (subscribed to just its
/// analysis variable: selection pushdown), live NetCDF conversion (full
/// subscription), and a raw step archiver (full subscription).  This is
/// the `stormio insitu` command: the multi-consumer analog of
/// `stormio follow`, with zero file-system round-trip.
///
/// When the resolved plan targets a **draining burst buffer**
/// (`adios2_target = 'bb'` + `adios2_drain = .true.`, or `'auto'`
/// resolving there) the pipeline rides the BB-local file path instead of
/// SST: the producer writes one live-published BP4 stream to the
/// node-local NVMe and the same three consumers follow it through
/// [`crate::adios::bp::follower::TieredFollower`]s — analyzing each step
/// at burst-buffer latency while the PFS drain proceeds behind them
/// (DESIGN.md §11).
pub fn run_insitu_from_namelist(
    path: &std::path::Path,
    artifacts: &std::path::Path,
) -> Result<RunSummary> {
    use crate::adios::engine::sst::{SstConsumer, SstSource};
    use crate::adios::Subscription;
    use crate::analysis::InsituAnalyzer;
    use crate::runtime::AnalysisStep;
    use std::time::Duration;

    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::config(format!("cannot read {}: {e}", path.display())))?;
    let nl = Namelist::parse(&text)?;
    let base = path.parent().unwrap_or(std::path::Path::new("."));
    let mut cfg = RunConfig::from_namelist(&nl, base)?;
    // This command *is* the streaming pipeline: force the ADIOS2 backend
    // regardless of the namelist's io_form so the engine below is what
    // the driver constructs.
    cfg.io_form = 22;

    // Load the runtime first: fail fast before any consumer blocks in
    // accept waiting for a producer that will never start.
    let rt = XlaRuntime::new()?;
    let man = Manifest::load(artifacts)?;
    let driver = ForecastDriver::new(cfg.forecast.clone())?;
    let (nyp, nxp) = driver.decomp.patch();
    let step = Arc::new(ModelStep::load(&rt, &man, nyp, nxp)?);

    let adios = cfg.adios(base)?;
    // Route on the *target intent* alone (not a fully-resolved plan):
    // this command provides its own SST consumer addresses below, so an
    // Address-less SST XML must not fail here, and a bb+drain request
    // must reach the BB-local pipeline regardless of the XML engine.
    let io = adios
        .config
        .io("wrf_history")
        .expect("declared by cfg.adios");
    let merged = cfg.intent.merge_io_config(io)?;
    let bb_local = match merged.target.setting {
        crate::plan::Setting::Explicit(crate::adios::Target::BurstBuffer { drain: true }) => true,
        crate::plan::Setting::Auto => {
            merged.drain.unwrap_or(true)
                && matches!(
                    cfg.planner()
                        .choose_target(merged.frames_per_outfile.unwrap_or(1)),
                    crate::adios::Target::BurstBuffer { .. }
                )
        }
        _ => false,
    };
    if bb_local {
        return run_insitu_bb_local(cfg, &adios, driver, step, &rt, &man);
    }

    let accept_timeout = Some(Duration::from_secs(300));
    let step_timeout = Duration::from_secs(300);

    let l_analysis = SstConsumer::listen("127.0.0.1:0")?;
    let l_convert = SstConsumer::listen("127.0.0.1:0")?;
    let l_archive = SstConsumer::listen("127.0.0.1:0")?;
    let addrs = [
        l_analysis.local_addr()?,
        l_convert.local_addr()?,
        l_archive.local_addr()?,
    ];

    let aot = AnalysisStep::load(&rt, &man, cfg.forecast.ny, cfg.forecast.nx).ok();
    let img_dir = cfg.out_dir.join("frames");
    let analysis_t = std::thread::spawn(move || -> Result<Vec<crate::analysis::AnalysisRecord>> {
        let analyzer = InsituAnalyzer::new(aot, Some(img_dir));
        let consumer = l_analysis.accept_with(&analyzer.subscription(), accept_timeout)?;
        analyzer.run(&mut SstSource::new(consumer), step_timeout)
    });
    let nc_dir = cfg.out_dir.join("nc_live");
    let nc_dir_t = nc_dir.clone();
    let convert_t = std::thread::spawn(move || -> Result<Vec<PathBuf>> {
        let consumer = l_convert.accept_with(&Subscription::all(), accept_timeout)?;
        crate::convert::stream_to_nc(
            &mut SstSource::new(consumer),
            &nc_dir_t,
            "wrfout",
            true,
            step_timeout,
        )
    });
    let arc_dir = cfg.out_dir.join("archive");
    let arc_dir_t = arc_dir.clone();
    let archive_t = std::thread::spawn(move || -> Result<Vec<PathBuf>> {
        let consumer = l_archive.accept_with(&Subscription::all(), accept_timeout)?;
        crate::convert::stream_to_archive(
            &mut SstSource::new(consumer),
            &arc_dir_t,
            "wrfout",
            step_timeout,
        )
    });

    // Producer: the forecast with an SST fan-out plan addressing all
    // three consumers (namelist engine choice is overridden — this
    // command *is* the streaming pipeline), plus the wire v4 service
    // broker so consumers can attach mid-stream (DESIGN.md §15).
    let mut intent = merged;
    intent.addresses = addrs.iter().map(|a| a.to_string()).collect();
    intent.sst_broker = Some(true);
    let plan = cfg.planner().plan(EngineKind::Sst, &intent)?;
    println!("{}", plan.summary_line());
    let adaptive = intent.adaptive.unwrap_or(false);
    let replans: Arc<Mutex<Vec<PlanChange>>> = Arc::new(Mutex::new(Vec::new()));

    // Fourth consumer, attached *late* through the broker: it discovers
    // the producer via the contact file rank 0 publishes at open, is
    // admitted at a step boundary, and receives the current step's
    // frames as replay from the shared crop cache.
    let contact = crate::adios::engine::sst::contact_path(&cfg.out_dir.join("pfs"));
    let late_t = std::thread::spawn(move || -> Result<(usize, usize, u64)> {
        use crate::adios::source::{StepSource, StepStatus};
        let addr = crate::adios::engine::sst::read_contact(&contact, Duration::from_secs(60))?;
        let consumer =
            SstConsumer::attach(&addr, &Subscription::all(), Some(Duration::from_secs(300)))?;
        let mut src = SstSource::new(consumer);
        let mut first = None;
        let (mut steps, mut bytes) = (0usize, 0u64);
        loop {
            match src.begin_step(step_timeout)? {
                StepStatus::Ready => {
                    first.get_or_insert(src.step_index());
                    bytes += src.step_stored_bytes();
                    steps += 1;
                    src.end_step()?;
                }
                StepStatus::EndOfStream | StepStatus::Timeout => break,
            }
        }
        Ok((first.unwrap_or(0), steps, bytes))
    });

    let summary = driver.run(step, |_rank| {
        if adaptive {
            cfg.make_adaptive_backend(&plan, &intent, replans.clone())
        } else {
            cfg.make_backend(&plan)
        }
        .expect("backend construction failed")
    })?;

    let records = analysis_t
        .join()
        .map_err(|_| Error::model("analysis consumer panicked"))??;
    let converted = convert_t
        .join()
        .map_err(|_| Error::model("conversion consumer panicked"))??;
    let archived = archive_t
        .join()
        .map_err(|_| Error::model("archive consumer panicked"))??;

    print_summary(&cfg, &summary);
    for c in replans.lock().expect("plan-changes sink poisoned").iter() {
        println!("{}", c.summary());
    }
    println!(
        "in-situ fan-out: {} frames analyzed (θ surface mean of last: {:.2}), \
         {} NetCDF files in {}, {} archived steps in {}",
        records.len(),
        records.last().map(|r| r.surf_mean).unwrap_or(0.0),
        converted.len(),
        nc_dir.display(),
        archived.len(),
        arc_dir.display(),
    );
    // The late joiner is best-effort: a very short run may close before
    // its admission boundary (the broker then refuses the parked attach).
    match late_t.join() {
        Ok(Ok((first, steps, bytes))) if steps > 0 => println!(
            "late-attach consumer: admitted at step {first}, streamed {steps} step(s) ({})",
            crate::util::human_bytes(bytes)
        ),
        Ok(Ok(_)) => println!("late-attach consumer: admitted after the final step (0 steps)"),
        Ok(Err(e)) => println!("late-attach consumer: not admitted ({e})"),
        Err(_) => println!("late-attach consumer: panicked"),
    }
    print_consumer_egress(&summary.frames, &["analysis", "convert", "archive", "late"]);
    Ok(summary)
}

/// Cadence/quota policy for the burst-buffer replica reaper: *when* to
/// sweep lives here in the launcher; *what is safe to remove* stays
/// entirely inside [`crate::adios::bp::follower::reap_bb_replicas`]'s
/// conservative drain-watermark check (a sweep during the run is a
/// no-op until the producer marks the stream complete).
#[derive(Debug, Clone, Copy)]
pub struct ReaperPolicy {
    /// Seconds between background sweeps.
    pub cadence: std::time::Duration,
    /// Maximum background sweeps per run (bounds reaper metadata I/O on
    /// the shared burst buffer); the shutdown sweep always runs.
    pub sweep_quota: u32,
}

impl Default for ReaperPolicy {
    fn default() -> Self {
        ReaperPolicy { cadence: std::time::Duration::from_millis(500), sweep_quota: 600 }
    }
}

/// Background burst-buffer replica reaper driven by a [`ReaperPolicy`]:
/// sweeps `reap_bb_replicas` on the policy cadence while the in-situ
/// pipeline runs, then once more at shutdown so replicas the drain
/// finished last are still trimmed.
struct BbReaper {
    stop: Arc<std::sync::atomic::AtomicBool>,
    handle: std::thread::JoinHandle<(u64, u32)>,
}

impl BbReaper {
    fn start(pfs_bp_dir: PathBuf, bb_root: PathBuf, policy: ReaperPolicy) -> BbReaper {
        use std::sync::atomic::{AtomicBool, Ordering};
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let mut freed = 0u64;
            let mut sweeps = 0u32;
            let slice = policy.cadence / 10 + std::time::Duration::from_millis(1);
            while !flag.load(Ordering::Relaxed) && sweeps < policy.sweep_quota {
                match crate::adios::bp::follower::reap_bb_replicas(&pfs_bp_dir, &bb_root) {
                    Ok(n) => {
                        freed += n;
                        sweeps += 1;
                    }
                    Err(e) => eprintln!("bb reaper: sweep failed: {e}"),
                }
                // Sleep in slices so shutdown isn't delayed by a full
                // cadence period.
                let slept = std::time::Instant::now();
                while slept.elapsed() < policy.cadence && !flag.load(Ordering::Relaxed) {
                    std::thread::sleep(slice);
                }
            }
            // Shutdown sweep: the drain typically completes only after
            // the producer closes, which is exactly when we get here.
            if let Ok(n) = crate::adios::bp::follower::reap_bb_replicas(&pfs_bp_dir, &bb_root) {
                freed += n;
                sweeps += 1;
            }
            (freed, sweeps)
        });
        BbReaper { stop, handle }
    }

    /// Signal the policy loop, run the shutdown sweep, and return
    /// `(bytes freed, sweeps run)`.
    fn finish(self) -> (u64, u32) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        self.handle.join().unwrap_or((0, 0))
    }
}

/// The BB-local in-situ pipeline (`stormio insitu` over a draining burst
/// buffer): one BP4 single-file producer publishing at burst-buffer
/// durability, three concurrent
/// [`crate::adios::bp::follower::TieredFollower`] consumers reading each
/// step from the fastest tier that holds it.  A background [`BbReaper`]
/// trims node-local replicas the PFS drain has fully superseded.
fn run_insitu_bb_local(
    cfg: RunConfig,
    adios: &Adios,
    driver: ForecastDriver,
    step: Arc<ModelStep>,
    rt: &XlaRuntime,
    man: &Manifest,
) -> Result<RunSummary> {
    use crate::adios::bp::follower::TieredFollower;
    use crate::analysis::InsituAnalyzer;
    use crate::runtime::AnalysisStep;
    use std::time::Duration;

    let step_timeout = Duration::from_secs(300);
    let poll = Duration::from_millis(20);

    // One long-lived BP4 stream (all frames in one outfile) publishing the
    // BB-local index per step — the producer never waits for the drain.
    // Start from the namelist/XML-resolved intent and force only what
    // this pipeline requires: the BP4 engine on a live-published draining
    // burst buffer, all frames in one outfile.
    let io = adios
        .config
        .io("wrf_history")
        .expect("declared by cfg.adios");
    let mut intent = cfg.intent.merge_io_config(io)?;
    intent.target = crate::plan::Knob::namelist(crate::plan::Setting::Explicit(
        crate::adios::Target::BurstBuffer { drain: true },
    ));
    intent.live_publish = Some(true);
    intent.frames_per_outfile = Some(0);
    let plan = cfg.planner().plan(EngineKind::Bp4, &intent)?;
    println!("{}", plan.summary_line());
    let adaptive = intent.adaptive.unwrap_or(false);
    let replans: Arc<Mutex<Vec<PlanChange>>> = Arc::new(Mutex::new(Vec::new()));

    let first_frame = usize::from(!cfg.forecast.write_t0);
    let bp_dir = cfg
        .out_dir
        .join("pfs")
        .join(format!("{}.bp", cfg.forecast.frame_name(first_frame)));
    let bb_root = cfg.out_dir.join("bb");

    let aot = AnalysisStep::load(rt, man, cfg.forecast.ny, cfg.forecast.nx).ok();
    let img_dir = cfg.out_dir.join("frames");
    let (bp_a, bb_a) = (bp_dir.clone(), bb_root.clone());
    let analysis_t = std::thread::spawn(
        move || -> Result<(Vec<crate::analysis::AnalysisRecord>, (usize, usize))> {
            let mut src = TieredFollower::open(&bp_a, &bb_a, poll)?;
            let analyzer = InsituAnalyzer::new(aot, Some(img_dir));
            let records = analyzer.run(&mut src, step_timeout)?;
            Ok((records, src.tier_counts()))
        },
    );
    let nc_dir = cfg.out_dir.join("nc_live");
    let (bp_c, bb_c, nc_dir_t) = (bp_dir.clone(), bb_root.clone(), nc_dir.clone());
    let convert_t = std::thread::spawn(
        move || -> Result<(Vec<PathBuf>, (usize, usize))> {
            let mut src = TieredFollower::open(&bp_c, &bb_c, poll)?;
            let paths =
                crate::convert::stream_to_nc(&mut src, &nc_dir_t, "wrfout", true, step_timeout)?;
            Ok((paths, src.tier_counts()))
        },
    );
    let arc_dir = cfg.out_dir.join("archive");
    let (bp_r, bb_r, arc_dir_t) = (bp_dir.clone(), bb_root.clone(), arc_dir.clone());
    let archive_t = std::thread::spawn(
        move || -> Result<(Vec<PathBuf>, (usize, usize))> {
            let mut src = TieredFollower::open(&bp_r, &bb_r, poll)?;
            let paths =
                crate::convert::stream_to_archive(&mut src, &arc_dir_t, "wrfout", step_timeout)?;
            Ok((paths, src.tier_counts()))
        },
    );
    // Replica reaper on the default cadence/quota policy: a no-op sweep
    // until the drain watermark proves replicas superseded.
    let reaper = BbReaper::start(bp_dir, bb_root, ReaperPolicy::default());

    let summary = driver.run(step, |_rank| {
        if adaptive {
            cfg.make_adaptive_backend(&plan, &intent, replans.clone())
        } else {
            cfg.make_backend(&plan)
        }
        .expect("backend construction failed")
    })?;

    let (records, tiers_a) = analysis_t
        .join()
        .map_err(|_| Error::model("analysis consumer panicked"))??;
    let (converted, tiers_c) = convert_t
        .join()
        .map_err(|_| Error::model("conversion consumer panicked"))??;
    let (archived, tiers_r) = archive_t
        .join()
        .map_err(|_| Error::model("archive consumer panicked"))??;

    print_summary(&cfg, &summary);
    for c in replans.lock().expect("plan-changes sink poisoned").iter() {
        println!("{}", c.summary());
    }
    println!(
        "in-situ over the burst buffer: {} frames analyzed (θ surface mean of \
         last: {:.2}), {} NetCDF files in {}, {} archived steps in {}",
        records.len(),
        records.last().map(|r| r.surf_mean).unwrap_or(0.0),
        converted.len(),
        nc_dir.display(),
        archived.len(),
        arc_dir.display(),
    );
    let mut t = Table::new(
        "steps served per tier (burst-buffer-local follow)",
        &["consumer", "burst buffer", "pfs"],
    );
    for (label, (bb, pfs)) in
        [("analysis", tiers_a), ("convert", tiers_c), ("archive", tiers_r)]
    {
        t.row(&[label.to_string(), bb.to_string(), pfs.to_string()]);
    }
    println!("{}", t.render());
    let (freed, sweeps) = reaper.finish();
    println!(
        "bb replica reaper: {sweeps} sweep(s), {} of superseded replicas freed",
        crate::util::human_bytes(freed)
    );
    Ok(summary)
}

/// The `stormio attach` command: join a *running* broker-enabled SST
/// producer mid-stream (wire v4, DESIGN.md §15) and tail its steps.
///
/// `target` is either a broker address (`host:port`) or a path — the
/// producer's output directory (or the `sst_broker.contact` file itself),
/// from which the broker address rank 0 published is read.  `sub_spec`
/// is an optional [`Subscription::parse`] spec (`'T[1:2,0:6];PSFC'`);
/// absent means subscribe to everything.  Admission lands at the
/// producer's next step boundary; the first step received is replayed
/// from the producer's shared crop cache.
pub fn run_attach(target: &str, sub_spec: Option<&str>, timeout_secs: u64) -> Result<()> {
    use crate::adios::engine::sst::{self, SstConsumer, SstSource};
    use crate::adios::source::{StepSource, StepStatus};
    use crate::adios::Subscription;
    use std::time::Duration;

    let timeout = Duration::from_secs(timeout_secs.max(1));
    let sub = match sub_spec {
        Some(s) => Subscription::parse(s)?,
        None => Subscription::all(),
    };
    let path = std::path::Path::new(target);
    let addr = if target.contains(':') && !path.exists() {
        target.to_string()
    } else {
        let contact = if path.is_dir() {
            // Accept the run directory or its pfs/ subdirectory.
            let pfs = path.join("pfs");
            if sst::contact_path(path).exists() || !pfs.is_dir() {
                sst::contact_path(path)
            } else {
                sst::contact_path(&pfs)
            }
        } else {
            path.to_path_buf()
        };
        sst::read_contact(&contact, timeout)?
    };
    println!("attaching to SST broker {addr} ...");
    let consumer = SstConsumer::attach(&addr, &sub, Some(timeout))?;
    let mut src = SstSource::new(consumer);
    let (mut steps, mut bytes) = (0usize, 0u64);
    loop {
        match src.begin_step(timeout)? {
            StepStatus::Ready => {
                let b = src.step_stored_bytes();
                println!(
                    "step {}: {} var(s), {}",
                    src.step_index(),
                    src.var_names().len(),
                    crate::util::human_bytes(b)
                );
                steps += 1;
                bytes += b;
                src.end_step()?;
            }
            StepStatus::EndOfStream => break,
            StepStatus::Timeout => {
                println!("no step within {}s; detaching", timeout.as_secs());
                break;
            }
        }
    }
    println!(
        "attached consumer received {steps} step(s), {} total",
        crate::util::human_bytes(bytes)
    );
    Ok(())
}

/// The `stormio relay` command: one node of the SST distribution tree
/// (DESIGN.md §16).  Subscribes to a running broker-enabled producer (or
/// an upper relay) mid-stream as an ordinary wire v4 consumer, and
/// re-serves every received step downstream through its own broker —
/// leaves (or deeper relays) join with `stormio attach <relay contact>`
/// and are admitted at this relay's next forwarded step.
///
/// `target` resolves exactly like `stormio attach`'s: a broker
/// `host:port`, the producer's output directory, or a
/// `sst_broker.contact` file.  `listen` binds the relay's own broker
/// (port 0 picks an ephemeral port, printed on start); `depth_hint`
/// labels the ledger with the relay's tree level.  Runs until the
/// upstream stream ends, then closes every downstream lane and prints
/// the per-hop ledger.
pub fn run_relay(target: &str, listen: &str, depth_hint: u32, timeout_secs: u64) -> Result<()> {
    use crate::adios::engine::sst::{self, RelayOpts, RelayUpstream, SstRelay};
    use std::time::Duration;

    let timeout = Duration::from_secs(timeout_secs.max(1));
    let path = std::path::Path::new(target);
    let addr = if target.contains(':') && !path.exists() {
        target.to_string()
    } else {
        let contact = if path.is_dir() {
            // Accept the run directory or its pfs/ subdirectory.
            let pfs = path.join("pfs");
            if sst::contact_path(path).exists() || !pfs.is_dir() {
                sst::contact_path(path)
            } else {
                sst::contact_path(&pfs)
            }
        } else {
            path.to_path_buf()
        };
        sst::read_contact(&contact, timeout)?
    };
    println!("relay (depth {depth_hint}): subscribing upstream at {addr} ...");
    let relay = SstRelay::open(
        RelayUpstream::Attach {
            broker_addr: addr,
            timeout: Some(timeout),
        },
        &[],
        RelayOpts {
            broker: true,
            broker_bind: listen.to_string(),
            depth_hint,
            ..RelayOpts::default()
        },
    )?;
    println!(
        "relay broker listening on {} — attach leaves with `stormio attach {}`",
        relay.broker_addr().as_deref().unwrap_or("?"),
        relay.broker_addr().as_deref().unwrap_or("?"),
    );
    let report = relay.run()?;
    let up: u64 = report.steps.iter().map(|s| s.relay_upstream_bytes).sum();
    let down: u64 = report.steps.iter().map(|s| s.relay_downstream_bytes).sum();
    let recut: u64 = report.steps.iter().map(|s| s.relay_crops_recut).sum();
    let admitted: u32 = report.steps.iter().map(|s| s.consumers_admitted).sum();
    let hop: f64 = report.steps.iter().map(|s| s.relay_hop_secs).sum();
    println!(
        "relay done: {} step(s) forwarded, {} received upstream, {} served \
         downstream ({} of producer egress relieved), {recut} crop(s) re-cut \
         here, {admitted} leaf join(s), {hop:.3}s total hop time",
        report.steps.len(),
        crate::util::human_bytes(up),
        crate::util::human_bytes(down),
        crate::util::human_bytes(down.saturating_sub(up)),
    );
    Ok(())
}

/// Print the per-consumer wire-egress table of a fan-out run (empty
/// egress vectors — file engines, single-consumer streams — print
/// nothing).  `labels` name the consumers in address order.
pub fn print_consumer_egress(frames: &[crate::io::api::FrameReport], labels: &[&str]) {
    let n = frames
        .iter()
        .map(|f| f.egress_per_consumer.len())
        .max()
        .unwrap_or(0);
    if n == 0 {
        return;
    }
    let mut totals = vec![0u64; n];
    for f in frames {
        for (i, e) in f.egress_per_consumer.iter().enumerate() {
            totals[i] += e;
        }
    }
    let sum: u64 = totals.iter().sum();
    let mut t = Table::new(
        "per-consumer wire egress (fan-out)",
        &["consumer", "label", "egress", "share"],
    );
    for (i, tot) in totals.iter().enumerate() {
        t.row(&[
            i.to_string(),
            labels.get(i).copied().unwrap_or("-").to_string(),
            crate::util::human_bytes(*tot),
            format!("{:.1}%", 100.0 * *tot as f64 / sum.max(1) as f64),
        ]);
    }
    println!("{}", t.render());
    // Shared-frame egress summary (DESIGN.md §14): how much codec work
    // the content-addressed frame cache collapsed across consumers.
    let unique: u64 = frames.iter().map(|f| f.unique_crops).sum();
    let hits: u64 = frames.iter().map(|f| f.crop_cache_hits).sum();
    let saved: u64 = frames.iter().map(|f| f.codec_passes_saved).sum();
    let deduped: u64 = frames.iter().map(|f| f.deduped_egress_bytes).sum();
    if unique + hits + saved + deduped > 0 {
        println!(
            "fan-out frame cache: {unique} unique crop(s) compressed, \
             {hits} cache hit(s), {saved} codec pass(es) saved, \
             {} of egress refcount-shared",
            crate::util::human_bytes(deduped)
        );
    }
    // Membership ledger (wire v4 service tier, DESIGN.md §15): silent for
    // v3 runs where membership is frozen at open.
    let admitted: u32 = frames.iter().map(|f| f.consumers_admitted).sum();
    let reaped: u32 = frames.iter().map(|f| f.consumers_reaped).sum();
    let rescoped: u32 = frames.iter().map(|f| f.consumers_rescoped).sum();
    let replayed: u64 = frames.iter().map(|f| f.replay_bytes).sum();
    if admitted as u64 + reaped as u64 + rescoped as u64 + replayed > 0 {
        println!(
            "membership: {admitted} admitted mid-stream, {reaped} reaped, \
             {rescoped} rescoped, {} replayed to joiners",
            crate::util::human_bytes(replayed)
        );
    }
}

/// WRF `rsl.out`-style end-of-run report.
pub fn print_summary(cfg: &RunConfig, s: &RunSummary) {
    println!("stormio forecast complete — backend {}", s.backend);
    println!(
        "grid {}x{}x{}  ranks {} ({} nodes × {}/node)  frames {}",
        cfg.forecast.nz,
        cfg.forecast.ny,
        cfg.forecast.nx,
        cfg.forecast.ranks,
        cfg.nodes,
        cfg.forecast.ranks_per_node,
        s.frames.len()
    );
    let mut t = Table::new(
        "history frames (virtual CONUS-scale times)",
        &["frame", "perceived [s]", "raw", "stored", "wall [s]"],
    );
    for f in &s.frames {
        t.row(&[
            f.name.clone(),
            format!("{:.3}", f.perceived()),
            crate::util::human_bytes(f.bytes_raw),
            crate::util::human_bytes(f.bytes_stored),
            format!("{:.3}", f.real_secs),
        ]);
    }
    println!("{}", t.render());
    println!(
        "timing: init {:.2}s  compute {:.2}s  io(wall) {:.2}s  mean perceived write {:.3}s",
        s.ledger.get("init"),
        s.ledger.get("compute"),
        s.ledger.get("io"),
        s.mean_perceived_write
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::operator::Codec;
    use crate::adios::Target;
    use crate::plan::{DecisionSource, Setting};

    const NL: &str = r#"
 &time_control
   history_interval = 30,
   frames = 2,
   io_form_history = 22,
   adios2_compression = 'zstd',
   adios2_num_aggregators = 2,
   adios2_target = 'bb',
   adios2_drain = .true.,
   adios2_sst_data_plane = 'funnel',
   adios2_sst_address = '127.0.0.1:5001, 127.0.0.1:5002',
   adios2_live_publish = .true.,
   frames_per_outfile = 0,
 /
 &domains
   e_we = 192, e_sn = 192, e_vert = 4,
   steps_per_history = 3,
 /
 &stormio
   ranks = 4, ranks_per_node = 2, nodes = 2,
   out_dir = 'out', seed = 7, volume_scale = 16.0,
 /
"#;

    #[test]
    fn namelist_to_runconfig() {
        let nl = Namelist::parse(NL).unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        assert_eq!(cfg.io_form, 22);
        assert_eq!(cfg.intent.codec.setting, Setting::Explicit(Codec::Zstd));
        assert_eq!(cfg.intent.aggregators.setting, Setting::Explicit(2));
        assert_eq!(
            cfg.intent.target.setting,
            Setting::Explicit(Target::BurstBuffer { drain: true })
        );
        assert_eq!(
            cfg.intent.addresses,
            vec!["127.0.0.1:5001".to_string(), "127.0.0.1:5002".to_string()]
        );
        assert_eq!(cfg.intent.live_publish, Some(true));
        assert_eq!(cfg.intent.frames_per_outfile, Some(0));
        assert_eq!(cfg.forecast.frames, 2);
        assert_eq!(cfg.forecast.steps_per_interval, 3);
        assert_eq!(cfg.out_dir, PathBuf::from("/base/out"));
        assert_eq!(cfg.hardware().volume_scale, 16.0);
        assert_eq!(cfg.hardware().nodes, 2);
        assert!(cfg.shape().step_bytes > 0.0);
    }

    #[test]
    fn adaptive_replan_namelist_builds_the_closed_loop_backend() {
        let nl = Namelist::parse(
            r#"
 &time_control
   io_form_history = 22,
   adios2_num_aggregators = 'auto',
   adios2_compression = 'auto',
   adios2_target = 'auto',
   adios2_adaptive_replan = .true.,
 /
 &domains
   e_we = 64, e_sn = 64, e_vert = 2,
 /
 &stormio
   ranks = 4, ranks_per_node = 2, nodes = 2, out_dir = 'out',
 /
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        let adios = cfg.adios(std::path::Path::new("/base")).unwrap();
        let merged = cfg.merged_intent(&adios).unwrap();
        assert_eq!(merged.adaptive, Some(true));
        let plan = cfg.resolve_plan(&adios).unwrap();
        let sink = Arc::new(Mutex::new(Vec::new()));
        let b = cfg.make_adaptive_backend(&plan, &merged, sink).unwrap();
        assert!(b.name().starts_with("adios2-"));
    }

    #[test]
    fn plan_respects_namelist_overrides() {
        let nl = Namelist::parse(NL).unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        let adios = cfg.adios(std::path::Path::new("/base")).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        assert_eq!(plan.aggs_per_node.value, 2);
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Namelist);
        assert_eq!(plan.codec.value, Codec::Zstd);
        assert_eq!(plan.target.value, Target::BurstBuffer { drain: true });
        assert!(plan.live_publish && plan.bb_live());
        assert_eq!(plan.frames_per_outfile, 0);
        // The provenance surfaces: decision table + summary line.
        assert!(plan.render("wrf_history").contains("[namelist]"));
        assert!(plan.summary_line().contains("aggs/node 2 [namelist]"));
    }

    #[test]
    fn auto_knobs_resolve_via_cost_model() {
        let nl = Namelist::parse(
            r#"
 &time_control
   io_form_history = 22,
   adios2_num_aggregators = 'auto',
   adios2_compression = 'auto',
   adios2_target = 'auto',
 /
 &domains
   e_we = 64, e_sn = 64, e_vert = 2,
 /
 &stormio
   ranks = 8, ranks_per_node = 4, nodes = 2, volume_scale = 160.0,
 /
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        let adios = cfg.adios(std::path::Path::new("/base")).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Auto);
        assert!(plan.aggs_per_node.value >= 1 && plan.aggs_per_node.value <= 4);
        assert_eq!(plan.codec.source, DecisionSource::Auto);
        assert_eq!(plan.target.source, DecisionSource::Auto);
        assert!(plan.predicted.t_write > 0.0);
        // Explicit values in the same namelist still override 'auto'
        // elsewhere (round-trip proof: re-parse with one pinned knob).
        let nl2 = Namelist::parse(
            r#"
 &time_control
   io_form_history = 22,
   adios2_num_aggregators = 3,
   adios2_compression = 'auto',
 /
 &domains
   e_we = 64, e_sn = 64, e_vert = 2,
 /
 &stormio
   ranks = 8, ranks_per_node = 4, nodes = 2,
 /
"#,
        )
        .unwrap();
        let cfg2 = RunConfig::from_namelist(&nl2, std::path::Path::new("/base")).unwrap();
        let adios2 = cfg2.adios(std::path::Path::new("/base")).unwrap();
        let plan2 = cfg2.resolve_plan(&adios2).unwrap();
        assert_eq!(plan2.aggs_per_node.value, 3);
        assert_eq!(plan2.aggs_per_node.source, DecisionSource::Namelist);
        assert_eq!(plan2.codec.source, DecisionSource::Auto);
    }

    #[test]
    fn object_target_namelist_resolves_end_to_end() {
        let nl = Namelist::parse(
            r#"
 &time_control
   io_form_history = 22,
   adios2_target = 'object',
 /
 &domains
   e_we = 64, e_sn = 64, e_vert = 2,
 /
 &stormio
   ranks = 8, ranks_per_node = 4, nodes = 2,
 /
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        let adios = cfg.adios(std::path::Path::new("/base")).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        assert_eq!(plan.target.value, Target::Object);
        assert_eq!(plan.target.source, DecisionSource::Namelist);
        assert!(plan.render("wrf_history").contains("object"));
        // An auto target under an 8-member ensemble resolves to the
        // object space through the three-way sweep.
        let nl = Namelist::parse(
            r#"
 &time_control
   io_form_history = 22,
   adios2_target = 'auto',
   adios2_ensemble_writers = 8,
 /
 &domains
   e_we = 64, e_sn = 64, e_vert = 2,
 /
 &stormio
   ranks = 8, ranks_per_node = 4, nodes = 2, volume_scale = 160.0,
 /
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        assert_eq!(cfg.intent.ensemble_writers, Some(8));
        let adios = cfg.adios(std::path::Path::new("/base")).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        assert_eq!(plan.target.value, Target::Object);
        assert_eq!(plan.target.source, DecisionSource::Auto);
    }

    #[test]
    fn sst_io_gets_data_plane_from_namelist() {
        let nl = Namelist::parse(NL).unwrap();
        let cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/base")).unwrap();
        let dir = std::env::temp_dir().join(format!("stormio_launch_sst_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        std::fs::write(
            dir.join("adios2.xml"),
            r#"<adios-config><io name="wrf_history">
              <engine type="SST"><parameter key="Address" value="127.0.0.1:1"/></engine>
            </io></adios-config>"#,
        )
        .unwrap();
        let mut cfg = cfg;
        cfg.adios_xml = Some("adios2.xml".to_string());
        let adios = cfg.adios(&dir).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        assert_eq!(plan.engine, EngineKind::Sst);
        assert_eq!(
            plan.data_plane.value,
            crate::adios::engine::sst::DataPlane::Funnel
        );
        assert_eq!(plan.data_plane.source, DecisionSource::Namelist);
        // The namelist's consumer list overrides the XML Address (the
        // multi-consumer fan-out surface).
        assert_eq!(
            plan.addresses(),
            vec!["127.0.0.1:5001".to_string(), "127.0.0.1:5002".to_string()]
        );
        assert_eq!(plan.aggs_per_node.value, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn every_io_form_constructs() {
        let nl = Namelist::parse(NL).unwrap();
        let mut cfg = RunConfig::from_namelist(&nl, std::path::Path::new("/tmp")).unwrap();
        let adios = cfg.adios(std::path::Path::new("/tmp")).unwrap();
        let plan = cfg.resolve_plan(&adios).unwrap();
        for form in [2, 11, 102, 22, 901] {
            cfg.io_form = form;
            cfg.nio_tasks = 1;
            assert!(cfg.make_backend(&plan).is_ok(), "io_form {form}");
        }
        cfg.io_form = 7;
        assert!(cfg.make_backend(&plan).is_err());
    }
}
