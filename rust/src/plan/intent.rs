//! User *intent* for the I/O configuration knobs: the typed, `'auto'`-aware
//! form of every `adios2_*` namelist entry and engine XML parameter.
//!
//! This module is the **only** place in the crate that parses the engine
//! tuning strings (`adios2_num_aggregators`, `adios2_compression`,
//! `adios2_target`/`adios2_drain`, `adios2_sst_data_plane`, and their XML
//! parameter twins `NumAggregatorsPerNode`, `Target`/`DrainBB`,
//! `DataPlane`).  Everything downstream consumes the typed
//! [`crate::plan::IoPlan`] the [`crate::plan::Planner`] derives from an
//! [`IoIntent`] — engines never re-parse knob strings.
//!
//! Every knob is a [`Knob`]: a three-state [`Setting`] (unset / `'auto'` /
//! explicit value) plus the [`Origin`] it came from, so the resolved plan
//! can report *why* each value was chosen (`stormio plan`).

use crate::adios::engine::sst::DataPlane;
use crate::adios::engine::Target;
use crate::adios::operator::{Codec, OperatorConfig};
use crate::adios::IoConfig;
use crate::namelist::{Group, Value};
use crate::{Error, Result};

/// Three-state knob value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Setting<T> {
    /// Not specified anywhere: fall through to the built-in default.
    Unset,
    /// The `'auto'` sentinel: delegate the decision to the cost-model
    /// planner.
    Auto,
    /// Pinned by the user (namelist or XML); the planner must honor it.
    Explicit(T),
}

impl<T> Setting<T> {
    pub fn is_unset(&self) -> bool {
        matches!(self, Setting::Unset)
    }
}

// Manual impls: the derived `Default` would demand `T: Default` even
// though the default variants never hold a `T`.
impl<T> Default for Setting<T> {
    fn default() -> Self {
        Setting::Unset
    }
}

/// Where a knob's setting came from (provenance for the decision table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Origin {
    /// Neither namelist nor XML mentioned the knob.
    #[default]
    None,
    /// A WRF `namelist.input` `adios2_*` entry (highest precedence).
    Namelist,
    /// An `adios2.xml` engine `<parameter>`.
    Xml,
}

/// One knob: setting + provenance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Knob<T> {
    pub setting: Setting<T>,
    pub origin: Origin,
}

impl<T> Default for Knob<T> {
    fn default() -> Self {
        Knob {
            setting: Setting::Unset,
            origin: Origin::None,
        }
    }
}

impl<T> Knob<T> {
    pub fn namelist(setting: Setting<T>) -> Self {
        Knob {
            setting,
            origin: Origin::Namelist,
        }
    }
    fn xml(setting: Setting<T>) -> Self {
        Knob {
            setting,
            origin: Origin::Xml,
        }
    }
    /// Fill an unset knob from a lower-precedence source.
    fn or(self, fallback: Knob<T>) -> Knob<T> {
        if self.setting.is_unset() {
            fallback
        } else {
            self
        }
    }
}

/// The declarative I/O intent: what the user asked for, before the
/// planner turns it into an [`crate::plan::IoPlan`].
#[derive(Debug, Clone, Default)]
pub struct IoIntent {
    /// `adios2_num_aggregators` / `NumAggregatorsPerNode` (per node).
    pub aggregators: Knob<usize>,
    /// `adios2_compression` / the XML `<operator>` codec.
    pub codec: Knob<Codec>,
    /// `adios2_target` + `adios2_drain` / `Target` + `DrainBB`.
    pub target: Knob<Target>,
    /// Namelist `adios2_drain`, kept separately so it still applies when
    /// the *target* comes from XML (whose `DrainBB` it overrides) or is
    /// left to the planner.
    pub drain: Option<bool>,
    /// `adios2_sst_data_plane` / `DataPlane`.
    pub data_plane: Knob<DataPlane>,
    /// SST consumer addresses (`adios2_sst_address`, comma-separated, or
    /// the XML `Address` parameter).
    pub addresses: Vec<String>,
    /// `adios2_live_publish` / `LivePublish`.
    pub live_publish: Option<bool>,
    /// `frames_per_outfile` / `FramesPerOutfile` (0 = single-file mode).
    pub frames_per_outfile: Option<usize>,
    /// `PackThreads` (compression fan-out; 0 = auto).
    pub pack_threads: Option<usize>,
    /// `AsyncIO` (background append/drain pipeline).
    pub async_io: Option<bool>,
    /// `adios2_ensemble_writers` / `EnsembleWriters`: concurrent
    /// ensemble-member runs sharing the final store.  Feeds the planner's
    /// three-way target sweep (cross-run PFS contention vs independent
    /// object-space puts); absent means the workload shape's own count.
    pub ensemble_writers: Option<usize>,
    /// `adios2_object_retain_steps` / `ObjectRetainSteps`: keep only the
    /// newest N committed steps in the object space, garbage-collecting
    /// older step objects after each commit (followers see a clean
    /// `visible_steps` watermark throughout).  Absent = retain forever;
    /// ignored by the file targets.
    pub object_retain_steps: Option<usize>,
    /// `adios2_sst_broker` / `Broker`: run the wire v4 consumer service
    /// broker on rank 0 so consumers can attach mid-stream (DESIGN.md
    /// §15).  Absent = no broker (v3-compatible frozen membership).
    pub sst_broker: Option<bool>,
    /// `adios2_sst_hello_timeout` / `HelloTimeout`: seconds to wait for a
    /// consumer's lane hello/subscription handshake.  Absent = the
    /// engine's built-in default
    /// ([`crate::adios::engine::sst::DEFAULT_HELLO_TIMEOUT`]).
    pub sst_hello_timeout: Option<u64>,
    /// `adios2_sst_max_lanes` / `MaxLanes`: sanity cap on the advertised
    /// lane count a consumer will fan-in (and the producer may open).
    /// Absent = [`crate::adios::engine::sst::DEFAULT_MAX_LANES`].
    pub sst_max_lanes: Option<u32>,
    /// `adios2_relay_fanout` / `RelayFanout`: branching factor of the SST
    /// relay distribution tree (DESIGN.md §16) — leaves per relay node.
    /// `0` pins direct lanes (no tree); `'auto'` lets the planner pick a
    /// branching from the consumer count; unset behaves like `0`.
    pub relay_fanout: Knob<usize>,
    /// `adios2_adaptive_replan` / `AdaptiveReplan`: close the planning
    /// loop — feed measured per-step drain/egress signals back into the
    /// planner and re-resolve `'auto'` knobs between steps (DESIGN.md
    /// §17).  Absent = open-loop (plan once, never revisit).
    pub adaptive: Option<bool>,
    /// Operator template from the XML `<operator>` element: preserves
    /// shuffle / lossy bit-rounding settings when only the codec is
    /// (re)decided.
    pub operator_base: Option<OperatorConfig>,
}

/// `'auto'`-aware string classifier shared by all knob parsers.
fn auto_or<T>(s: &str, parse: impl FnOnce(&str) -> Result<T>) -> Result<Setting<T>> {
    if s.eq_ignore_ascii_case("auto") {
        Ok(Setting::Auto)
    } else {
        Ok(Setting::Explicit(parse(s)?))
    }
}

fn parse_target(s: &str, drain: bool) -> Result<Target> {
    match s.to_ascii_lowercase().as_str() {
        "pfs" | "filesystem" => Ok(Target::Pfs),
        "bb" | "burstbuffer" | "nvme" => Ok(Target::BurstBuffer { drain }),
        "object" | "objectstore" | "obj" => Ok(Target::Object),
        other => Err(Error::config(format!("unknown target `{other}`"))),
    }
}

impl IoIntent {
    /// Parse the `adios2_*` knobs out of a namelist `&time_control` group.
    /// Absent keys stay [`Setting::Unset`] (so XML, then defaults, apply);
    /// the string `'auto'` delegates to the planner.
    pub fn from_time_control(tc: &Group) -> Result<IoIntent> {
        let mut intent = IoIntent::default();

        if let Some(v) = tc.get("adios2_num_aggregators") {
            let setting = match v {
                Value::Int(i) if *i >= 1 => Setting::Explicit(*i as usize),
                Value::Int(i) => {
                    return Err(Error::config(format!(
                        "adios2_num_aggregators = {i} must be >= 1 (or 'auto')"
                    )))
                }
                Value::Str(s) => auto_or(s, |s| {
                    s.parse::<usize>().map_err(|_| {
                        Error::config(format!(
                            "adios2_num_aggregators = '{s}' is neither an integer nor 'auto'"
                        ))
                    })
                })?,
                other => {
                    return Err(Error::config(format!(
                        "adios2_num_aggregators = {other} is neither an integer nor 'auto'"
                    )))
                }
            };
            intent.aggregators = Knob::namelist(setting);
        }

        if let Some(s) = tc.get_str("adios2_compression") {
            intent.codec = Knob::namelist(auto_or(s, Codec::parse)?);
        }

        intent.drain = tc.get_bool("adios2_drain");
        let drain = intent.drain.unwrap_or(false);
        if let Some(s) = tc.get_str("adios2_target") {
            intent.target = Knob::namelist(auto_or(s, |s| parse_target(s, drain))?);
        }

        if let Some(s) = tc.get_str("adios2_sst_data_plane") {
            intent.data_plane = Knob::namelist(auto_or(s, DataPlane::parse)?);
        }

        if let Some(s) = tc.get_str("adios2_sst_address") {
            intent.addresses = split_addresses(s);
        }
        if let Some(b) = tc.get_bool("adios2_live_publish") {
            intent.live_publish = Some(b);
        }
        if let Some(n) = tc.get_i64("frames_per_outfile") {
            intent.frames_per_outfile = Some(n.max(0) as usize);
        }
        if let Some(n) = tc.get_i64("adios2_ensemble_writers") {
            if n < 1 {
                return Err(Error::config(format!(
                    "adios2_ensemble_writers = {n} must be >= 1"
                )));
            }
            intent.ensemble_writers = Some(n as usize);
        }
        if let Some(n) = tc.get_i64("adios2_object_retain_steps") {
            if n < 1 {
                return Err(Error::config(format!(
                    "adios2_object_retain_steps = {n} must be >= 1 \
                     (omit the key to retain every step)"
                )));
            }
            intent.object_retain_steps = Some(n as usize);
        }
        if let Some(b) = tc.get_bool("adios2_sst_broker") {
            intent.sst_broker = Some(b);
        }
        if let Some(b) = tc.get_bool("adios2_adaptive_replan") {
            intent.adaptive = Some(b);
        }
        if let Some(n) = tc.get_i64("adios2_sst_hello_timeout") {
            if n < 1 {
                return Err(Error::config(format!(
                    "adios2_sst_hello_timeout = {n} must be >= 1 second"
                )));
            }
            intent.sst_hello_timeout = Some(n as u64);
        }
        if let Some(n) = tc.get_i64("adios2_sst_max_lanes") {
            if n < 1 {
                return Err(Error::config(format!(
                    "adios2_sst_max_lanes = {n} must be >= 1"
                )));
            }
            intent.sst_max_lanes = Some(n as u32);
        }
        if let Some(v) = tc.get("adios2_relay_fanout") {
            let setting = match v {
                Value::Int(i) if *i >= 0 => Setting::Explicit(*i as usize),
                Value::Int(i) => {
                    return Err(Error::config(format!(
                        "adios2_relay_fanout = {i} must be >= 0 (0 = direct lanes, \
                         or 'auto')"
                    )))
                }
                Value::Str(s) => auto_or(s, |s| {
                    s.parse::<usize>().map_err(|_| {
                        Error::config(format!(
                            "adios2_relay_fanout = '{s}' is neither an integer nor 'auto'"
                        ))
                    })
                })?,
                other => {
                    return Err(Error::config(format!(
                        "adios2_relay_fanout = {other} is neither an integer nor 'auto'"
                    )))
                }
            };
            intent.relay_fanout = Knob::namelist(setting);
        }
        Ok(intent)
    }

    /// Fill every unset knob from an `adios2.xml` [`IoConfig`]'s engine
    /// parameters (namelist wins over XML, matching the paper's §IV
    /// precedence), and pick up the XML `<operator>` as the codec
    /// template.  XML parameter values may also be `'auto'`.
    pub fn merge_io_config(&self, io: &IoConfig) -> Result<IoIntent> {
        let mut merged = self.clone();

        if let Some(s) = io.param("NumAggregatorsPerNode") {
            let setting = auto_or(s, |s| {
                s.parse::<usize>()
                    .ok()
                    .filter(|n| *n >= 1)
                    .ok_or_else(|| {
                        Error::config(format!(
                            "NumAggregatorsPerNode={s} is neither a positive integer nor 'auto'"
                        ))
                    })
            })?;
            merged.aggregators = merged.aggregators.or(Knob::xml(setting));
        }
        // The namelist's standalone adios2_drain overrides XML DrainBB.
        let drain = match self.drain {
            Some(d) => d,
            None => io.param_bool("DrainBB", false)?,
        };
        if let Some(s) = io.param("Target") {
            merged.target = merged
                .target
                .or(Knob::xml(auto_or(s, |s| parse_target(s, drain))?));
        }
        if let Some(s) = io.param("DataPlane") {
            merged.data_plane = merged
                .data_plane
                .or(Knob::xml(auto_or(s, DataPlane::parse)?));
        }
        if io.operator.codec != Codec::None || self.codec.setting.is_unset() {
            merged.operator_base = Some(io.operator);
        }
        if merged.codec.setting.is_unset() && io.operator.codec != Codec::None {
            merged.codec = Knob::xml(Setting::Explicit(io.operator.codec));
        }
        if merged.addresses.is_empty() {
            if let Some(s) = io.param("Address") {
                merged.addresses = split_addresses(s);
            }
        }
        if merged.live_publish.is_none() {
            merged.live_publish = Some(io.param_bool("LivePublish", false)?);
        }
        if merged.frames_per_outfile.is_none() {
            merged.frames_per_outfile = Some(io.param_usize("FramesPerOutfile", 1)?);
        }
        if merged.pack_threads.is_none() {
            merged.pack_threads = Some(io.param_usize("PackThreads", 0)?);
        }
        if merged.async_io.is_none() {
            merged.async_io = Some(io.param_bool("AsyncIO", true)?);
        }
        if merged.ensemble_writers.is_none() {
            if let Some(s) = io.param("EnsembleWriters") {
                let n = s.parse::<usize>().ok().filter(|n| *n >= 1).ok_or_else(|| {
                    Error::config(format!(
                        "EnsembleWriters={s} is not a positive integer"
                    ))
                })?;
                merged.ensemble_writers = Some(n);
            }
        }
        if merged.object_retain_steps.is_none() {
            if let Some(s) = io.param("ObjectRetainSteps") {
                let n = s.parse::<usize>().ok().filter(|n| *n >= 1).ok_or_else(|| {
                    Error::config(format!(
                        "ObjectRetainSteps={s} is not a positive integer"
                    ))
                })?;
                merged.object_retain_steps = Some(n);
            }
        }
        if merged.sst_broker.is_none() && io.param("Broker").is_some() {
            merged.sst_broker = Some(io.param_bool("Broker", false)?);
        }
        if merged.adaptive.is_none() && io.param("AdaptiveReplan").is_some() {
            merged.adaptive = Some(io.param_bool("AdaptiveReplan", false)?);
        }
        if merged.sst_hello_timeout.is_none() {
            if let Some(s) = io.param("HelloTimeout") {
                let n = s.parse::<u64>().ok().filter(|n| *n >= 1).ok_or_else(|| {
                    Error::config(format!(
                        "HelloTimeout={s} is not a positive integer (seconds)"
                    ))
                })?;
                merged.sst_hello_timeout = Some(n);
            }
        }
        if merged.sst_max_lanes.is_none() {
            if let Some(s) = io.param("MaxLanes") {
                let n = s.parse::<u32>().ok().filter(|n| *n >= 1).ok_or_else(|| {
                    Error::config(format!("MaxLanes={s} is not a positive integer"))
                })?;
                merged.sst_max_lanes = Some(n);
            }
        }
        if let Some(s) = io.param("RelayFanout") {
            let setting = auto_or(s, |s| {
                s.parse::<usize>().map_err(|_| {
                    Error::config(format!(
                        "RelayFanout={s} is neither a non-negative integer nor 'auto'"
                    ))
                })
            })?;
            merged.relay_fanout = merged.relay_fanout.or(Knob::xml(setting));
        }
        Ok(merged)
    }
}

/// Split a comma-separated SST consumer address list.
pub fn split_addresses(s: &str) -> Vec<String> {
    s.split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::EngineKind;
    use crate::namelist::Namelist;

    fn tc(body: &str) -> Group {
        let nl = Namelist::parse(&format!("&time_control\n{body}\n/\n")).unwrap();
        nl.group("time_control").unwrap().clone()
    }

    #[test]
    fn explicit_auto_and_unset_parse() {
        let g = tc("adios2_num_aggregators = 2,\n adios2_compression = 'auto',");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.aggregators.setting, Setting::Explicit(2));
        assert_eq!(i.aggregators.origin, Origin::Namelist);
        assert_eq!(i.codec.setting, Setting::Auto);
        assert!(i.target.setting.is_unset());
        assert!(i.data_plane.setting.is_unset());
    }

    #[test]
    fn aggregator_auto_string_and_bad_values() {
        let g = tc("adios2_num_aggregators = 'auto',");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.aggregators.setting, Setting::Auto);
        assert!(IoIntent::from_time_control(&tc("adios2_num_aggregators = 0,")).is_err());
        assert!(IoIntent::from_time_control(&tc("adios2_num_aggregators = 'many',")).is_err());
        assert!(IoIntent::from_time_control(&tc("adios2_compression = 'snappy',")).is_err());
        assert!(IoIntent::from_time_control(&tc("adios2_target = 'tape',")).is_err());
    }

    #[test]
    fn object_target_and_ensemble_writers_parse() {
        let g = tc("adios2_target = 'object',\n adios2_ensemble_writers = 8,");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.target.setting, Setting::Explicit(Target::Object));
        assert_eq!(i.target.origin, Origin::Namelist);
        assert_eq!(i.ensemble_writers, Some(8));
        // The drain flag is meaningless for the object space and must not
        // perturb the parse.
        let g = tc("adios2_target = 'object',\n adios2_drain = .true.,");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.target.setting, Setting::Explicit(Target::Object));
        assert!(
            IoIntent::from_time_control(&tc("adios2_ensemble_writers = 0,")).is_err()
        );
        // XML spelling.
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params.insert("Target".into(), "object".into());
        io.params.insert("EnsembleWriters".into(), "4".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.target.setting, Setting::Explicit(Target::Object));
        assert_eq!(m.target.origin, Origin::Xml);
        assert_eq!(m.ensemble_writers, Some(4));
    }

    #[test]
    fn object_retain_steps_parses_both_spellings() {
        let g = tc("adios2_object_retain_steps = 3,");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.object_retain_steps, Some(3));
        assert!(
            IoIntent::from_time_control(&tc("adios2_object_retain_steps = 0,")).is_err()
        );
        // XML spelling fills only when the namelist is silent.
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params.insert("ObjectRetainSteps".into(), "2".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.object_retain_steps, Some(2));
        let m = i.merge_io_config(&io).unwrap();
        assert_eq!(m.object_retain_steps, Some(3));
        io.params.insert("ObjectRetainSteps".into(), "zero".into());
        assert!(IoIntent::default().merge_io_config(&io).is_err());
    }

    #[test]
    fn sst_service_knobs_parse_both_spellings() {
        let g = tc(
            "adios2_sst_broker = .true.,\n adios2_sst_hello_timeout = 5,\n \
             adios2_sst_max_lanes = 64,",
        );
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.sst_broker, Some(true));
        assert_eq!(i.sst_hello_timeout, Some(5));
        assert_eq!(i.sst_max_lanes, Some(64));
        assert!(
            IoIntent::from_time_control(&tc("adios2_sst_hello_timeout = 0,")).is_err()
        );
        assert!(IoIntent::from_time_control(&tc("adios2_sst_max_lanes = 0,")).is_err());
        // XML spellings fill only when the namelist is silent.
        let mut io = IoConfig::new("hist", EngineKind::Sst);
        io.params.insert("Broker".into(), "true".into());
        io.params.insert("HelloTimeout".into(), "9".into());
        io.params.insert("MaxLanes".into(), "8".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.sst_broker, Some(true));
        assert_eq!(m.sst_hello_timeout, Some(9));
        assert_eq!(m.sst_max_lanes, Some(8));
        let m = i.merge_io_config(&io).unwrap();
        assert_eq!(m.sst_hello_timeout, Some(5));
        assert_eq!(m.sst_max_lanes, Some(64));
        io.params.insert("HelloTimeout".into(), "soon".into());
        assert!(IoIntent::default().merge_io_config(&io).is_err());
    }

    #[test]
    fn adaptive_replan_parses_both_spellings() {
        let i =
            IoIntent::from_time_control(&tc("adios2_adaptive_replan = .true.,")).unwrap();
        assert_eq!(i.adaptive, Some(true));
        // Absent stays open-loop.
        assert_eq!(IoIntent::default().adaptive, None);
        // XML spelling fills only when the namelist is silent.
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params.insert("AdaptiveReplan".into(), "true".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.adaptive, Some(true));
        let nl =
            IoIntent::from_time_control(&tc("adios2_adaptive_replan = .false.,")).unwrap();
        let m = nl.merge_io_config(&io).unwrap();
        assert_eq!(m.adaptive, Some(false));
    }

    #[test]
    fn relay_fanout_parses_both_spellings() {
        let g = tc("adios2_relay_fanout = 'auto',");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.relay_fanout.setting, Setting::Auto);
        assert_eq!(i.relay_fanout.origin, Origin::Namelist);
        // 0 is a legal pin: direct lanes, no tree.
        let i = IoIntent::from_time_control(&tc("adios2_relay_fanout = 0,")).unwrap();
        assert_eq!(i.relay_fanout.setting, Setting::Explicit(0));
        let i = IoIntent::from_time_control(&tc("adios2_relay_fanout = 4,")).unwrap();
        assert_eq!(i.relay_fanout.setting, Setting::Explicit(4));
        assert!(IoIntent::from_time_control(&tc("adios2_relay_fanout = -1,")).is_err());
        assert!(
            IoIntent::from_time_control(&tc("adios2_relay_fanout = 'wide',")).is_err()
        );
        // Unset stays unset (the planner then renders no relay row).
        let i = IoIntent::from_time_control(&tc("adios2_sst_broker = .true.,")).unwrap();
        assert!(i.relay_fanout.setting.is_unset());
        // XML spelling fills only when the namelist is silent.
        let mut io = IoConfig::new("hist", EngineKind::Sst);
        io.params.insert("RelayFanout".into(), "3".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.relay_fanout.setting, Setting::Explicit(3));
        assert_eq!(m.relay_fanout.origin, Origin::Xml);
        let nl = IoIntent::from_time_control(&tc("adios2_relay_fanout = 2,")).unwrap();
        let m = nl.merge_io_config(&io).unwrap();
        assert_eq!(m.relay_fanout.setting, Setting::Explicit(2));
        assert_eq!(m.relay_fanout.origin, Origin::Namelist);
        io.params.insert("RelayFanout".into(), "tree".into());
        assert!(IoIntent::default().merge_io_config(&io).is_err());
    }

    #[test]
    fn target_folds_drain_flag() {
        let g = tc("adios2_target = 'bb',\n adios2_drain = .true.,");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(
            i.target.setting,
            Setting::Explicit(Target::BurstBuffer { drain: true })
        );
        let g = tc("adios2_target = 'auto',\n adios2_drain = .true.,");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.target.setting, Setting::Auto);
    }

    #[test]
    fn xml_fills_only_unset_knobs() {
        let g = tc("adios2_num_aggregators = 4,");
        let nl_intent = IoIntent::from_time_control(&g).unwrap();
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params
            .insert("NumAggregatorsPerNode".into(), "2".into());
        io.params.insert("Target".into(), "burstbuffer".into());
        io.params.insert("DrainBB".into(), "true".into());
        io.operator = OperatorConfig::blosc(Codec::Zstd);
        let m = nl_intent.merge_io_config(&io).unwrap();
        // Namelist value survives the merge; XML fills the rest.
        assert_eq!(m.aggregators.setting, Setting::Explicit(4));
        assert_eq!(m.aggregators.origin, Origin::Namelist);
        assert_eq!(
            m.target.setting,
            Setting::Explicit(Target::BurstBuffer { drain: true })
        );
        assert_eq!(m.target.origin, Origin::Xml);
        assert_eq!(m.codec.setting, Setting::Explicit(Codec::Zstd));
        assert_eq!(m.codec.origin, Origin::Xml);
        assert_eq!(m.operator_base, Some(OperatorConfig::blosc(Codec::Zstd)));
        assert_eq!(m.frames_per_outfile, Some(1));
        assert_eq!(m.async_io, Some(true));
    }

    #[test]
    fn namelist_drain_overrides_xml_drainbb() {
        // adios2_drain without adios2_target must still apply when the
        // target itself comes from XML (which says DrainBB=false).
        let g = tc("adios2_drain = .true.,");
        let i = IoIntent::from_time_control(&g).unwrap();
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params.insert("Target".into(), "burstbuffer".into());
        io.params.insert("DrainBB".into(), "false".into());
        let m = i.merge_io_config(&io).unwrap();
        assert_eq!(
            m.target.setting,
            Setting::Explicit(Target::BurstBuffer { drain: true })
        );
    }

    #[test]
    fn xml_auto_sentinel_accepted() {
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params
            .insert("NumAggregatorsPerNode".into(), "auto".into());
        let m = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m.aggregators.setting, Setting::Auto);
        assert_eq!(m.aggregators.origin, Origin::Xml);
    }

    #[test]
    fn address_lists_split_and_precedence() {
        let g = tc("adios2_sst_address = '127.0.0.1:5001, 127.0.0.1:5002',");
        let i = IoIntent::from_time_control(&g).unwrap();
        assert_eq!(i.addresses, vec!["127.0.0.1:5001", "127.0.0.1:5002"]);
        let mut io = IoConfig::new("hist", EngineKind::Sst);
        io.params.insert("Address".into(), "127.0.0.1:9".into());
        let m = i.merge_io_config(&io).unwrap();
        assert_eq!(m.addresses, vec!["127.0.0.1:5001", "127.0.0.1:5002"]);
        let m2 = IoIntent::default().merge_io_config(&io).unwrap();
        assert_eq!(m2.addresses, vec!["127.0.0.1:9"]);
    }
}
