//! Cost-model-driven I/O planning (DESIGN.md §12).
//!
//! One typed [`IoPlan`] carries every engine knob from the configuration
//! surface (namelist `adios2_*` entries, `adios2.xml` parameters) to the
//! engines.  The flow is:
//!
//! ```text
//! namelist &time_control ──► IoIntent::from_time_control  (the ONLY
//! adios2.xml <io> params ──► IoIntent::merge_io_config     knob parsers)
//!                                  │
//!              workload shape ──► Planner::plan ◄── sim::CostModel
//!                                  │
//!                                IoPlan ──► open_engine (BP4 / SST / null)
//! ```
//!
//! Every knob supports the `'auto'` sentinel: the [`Planner`] then derives
//! the value from the cost model (aggregator sweep, fan-out-vs-relay
//! scoring, codec-throughput-vs-store-bandwidth) and records the decision
//! with its provenance, which `stormio plan` prints as a dry-run table and
//! [`IoPlan::stamp`] embeds into `BENCH_*.json` artifacts.
//!
//! With `adios2_adaptive_replan` the loop closes (DESIGN.md §17): the
//! engines' measured per-step signals flow back through
//! [`feedback::FeedbackController`], which re-resolves the `'auto'` knobs
//! between steps under the measured testbed — hysteresis keeps a healthy
//! run bit-identical to the open-loop path.

pub mod feedback;
pub mod intent;
pub mod planner;

use std::path::Path;
use std::time::Duration;

use crate::adios::engine::{bp4, sst};
use crate::adios::{Engine, EngineKind, IoConfig, NullEngine};
use crate::cluster::Comm;
use crate::sim::CostModel;
use crate::Result;

pub use feedback::{stamp_changes, FeedbackController, PlanChange, ReplanPolicy, Trigger};
pub use intent::{IoIntent, Knob, Origin, Setting};
pub use planner::{
    CodecProfile, ConsumerPlan, Decision, DecisionSource, IoPlan, PlanCosts, Planner,
    WorkloadShape,
};

/// Resolve an XML/declared [`IoConfig`] into an [`IoPlan`] with no
/// namelist intent on top — the library-level path used by
/// [`crate::adios::Adios::open_write`] (benches and tests that configure
/// engines straight from XML params).  `shape` defaults to the paper's
/// CONUS frame when the caller has no better estimate; it only matters
/// for `'auto'` knobs.
pub fn resolve_io(io: &IoConfig, cost: &CostModel, shape: WorkloadShape) -> Result<IoPlan> {
    let intent = IoIntent::default().merge_io_config(io)?;
    Planner::new(cost.clone(), shape).plan(io.engine.clone(), &intent)
}

/// Open a write engine from a resolved plan — the single construction
/// path for every engine: no string params are re-parsed here.
pub fn open_engine(
    plan: &IoPlan,
    output_name: &str,
    pfs_dir: &Path,
    bb_root: &Path,
    cost: CostModel,
    comm: &Comm,
) -> Result<Box<dyn Engine>> {
    match plan.engine {
        EngineKind::Bp4 => {
            let cfg = bp4::Bp4Config {
                name: output_name.to_string(),
                pfs_dir: pfs_dir.to_path_buf(),
                bb_root: bb_root.to_path_buf(),
                target: plan.target.value,
                operator: plan.operator,
                aggs_per_node: plan.aggs_per_node.value,
                cost,
                pack_threads: plan.pack_threads,
                async_io: plan.async_io,
                drain_throttle: None,
                live_publish: plan.live_publish,
                object_retain_steps: plan.object_retain_steps,
            };
            Ok(Box::new(bp4::Bp4Engine::open(cfg, comm)?))
        }
        EngineKind::Sst => {
            // The service tier (DESIGN.md §15): a broker-enabled plan
            // runs the wire v4 admission broker on rank 0 and publishes
            // its address through a contact file in the output directory
            // for late `SstConsumer::attach` joiners.
            let opts = sst::SstServiceOpts {
                broker: plan.broker,
                broker_bind: "127.0.0.1:0".into(),
                hello_timeout: plan
                    .sst_hello_timeout
                    .map(Duration::from_secs)
                    .unwrap_or(sst::DEFAULT_HELLO_TIMEOUT),
                max_lanes: plan.sst_max_lanes.unwrap_or(sst::DEFAULT_MAX_LANES),
                contact_file: plan.broker.then(|| sst::contact_path(pfs_dir)),
            };
            Ok(Box::new(sst::SstEngine::open_service(
                &plan.addresses(),
                plan.operator,
                cost,
                comm,
                Duration::from_secs(30),
                plan.data_plane.value,
                plan.aggs_per_node.value,
                opts,
            )?))
        }
        EngineKind::Null => Ok(Box::new(NullEngine::default())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::operator::{Codec, OperatorConfig};
    use crate::adios::Target;
    use crate::sim::HardwareSpec;

    #[test]
    fn resolve_io_honors_xml_params_and_defaults() {
        let cm = CostModel::new(HardwareSpec::paper_testbed(2));
        let mut io = IoConfig::new("hist", EngineKind::Bp4);
        io.params
            .insert("NumAggregatorsPerNode".into(), "2".into());
        io.params.insert("Target".into(), "burstbuffer".into());
        io.params.insert("DrainBB".into(), "true".into());
        io.operator = OperatorConfig::blosc(Codec::Zstd);
        let plan = resolve_io(&io, &cm, WorkloadShape::paper()).unwrap();
        assert_eq!(plan.aggs_per_node.value, 2);
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Xml);
        assert_eq!(plan.target.value, Target::BurstBuffer { drain: true });
        assert_eq!(plan.codec.value, Codec::Zstd);
        assert_eq!(plan.operator, OperatorConfig::blosc(Codec::Zstd));
        // Bare defaults: one aggregator, no codec, PFS.
        let bare = IoConfig::new("hist", EngineKind::Bp4);
        let plan = resolve_io(&bare, &cm, WorkloadShape::paper()).unwrap();
        assert_eq!(plan.aggs_per_node.value, 1);
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Default);
        assert_eq!(plan.codec.value, Codec::None);
        assert_eq!(plan.target.value, Target::Pfs);
        assert_eq!(plan.frames_per_outfile, 1);
        assert!(plan.async_io);
    }
}
