//! The cost-model planner: turns an [`IoIntent`] plus the workload shape
//! and virtual-testbed [`CostModel`] into one fully-resolved [`IoPlan`].
//!
//! Decision rules (DESIGN.md §12):
//!
//! * **aggregators per node** — sweep the divisors of `ranks_per_node`
//!   and take the argmin of the per-step cost `t_chain_gather +
//!   write + amortized MDS creates` ([`CostModel::t_bp4_perceived`]);
//! * **target** — burst buffer (with drain) when the best NVMe-landing
//!   sweep point beats the best PFS sweep point, else PFS;
//! * **codec** — argmin over `{none} ∪ codecs` of `t_compress +
//!   t_chain_gather(stored) + write(stored)` using the [`CodecProfile`]
//!   throughput/ratio table: compression is chosen only when the codec
//!   can keep up with the landing store's bandwidth;
//! * **data plane** — [`CostModel::fanout_advantage`] ≥ 1 picks the
//!   parallel lanes, < 1 the rank-0 funnel (latency-dominated
//!   many-consumer cases).
//!
//! Every decision records its [`DecisionSource`] so `stormio plan` and
//! the bench provenance ([`IoPlan::stamp`]) can show *why* each knob has
//! its value.

use std::fmt;

use crate::adios::engine::sst::DataPlane;
use crate::adios::engine::Target;
use crate::adios::operator::{Codec, CodecThroughput, OperatorConfig};
use crate::adios::EngineKind;
use crate::metrics::BenchReport;
use crate::sim::CostModel;
use crate::{Error, Result};

use super::intent::{IoIntent, Knob, Origin, Setting};

/// Where a resolved plan value came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionSource {
    /// Pinned in the namelist.
    Namelist,
    /// Pinned in `adios2.xml`.
    Xml,
    /// Built-in default (knob unset everywhere).
    Default,
    /// Chosen by the cost-model planner (`'auto'`).
    Auto,
}

impl fmt::Display for DecisionSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DecisionSource::Namelist => "namelist",
            DecisionSource::Xml => "xml",
            DecisionSource::Default => "default",
            DecisionSource::Auto => "auto",
        })
    }
}

/// A resolved knob value plus its provenance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision<T> {
    pub value: T,
    pub source: DecisionSource,
}

impl<T> Decision<T> {
    fn new(value: T, source: DecisionSource) -> Self {
        Decision { value, source }
    }
}

/// Resolve one knob: explicit values pass through with their origin,
/// `'auto'` runs the planner's chooser, unset takes the default.
fn decide<T>(knob: Knob<T>, auto: impl FnOnce() -> T, default: T) -> Decision<T> {
    match knob.setting {
        Setting::Explicit(v) => Decision::new(
            v,
            match knob.origin {
                Origin::Namelist => DecisionSource::Namelist,
                Origin::Xml => DecisionSource::Xml,
                Origin::None => DecisionSource::Default,
            },
        ),
        Setting::Auto => Decision::new(auto(), DecisionSource::Auto),
        Setting::Unset => Decision::new(default, DecisionSource::Default),
    }
}

/// The workload shape the planner scores against: the virtual
/// (CONUS-scale) byte volume of one history step, and how many
/// concurrent *runs* share the final store.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadShape {
    /// Uncompressed virtual bytes of one step (physical × volume_scale).
    pub step_bytes: f64,
    /// Concurrent ensemble-member runs writing to the shared final store
    /// (1 = a lone run, the paper's fig 4/7 regime).  Drives the
    /// three-way target sweep: N runs contend on one PFS file tree but
    /// put into the object space independently (DESIGN.md §13).
    pub writers: usize,
}

impl WorkloadShape {
    /// The paper's CONUS 2.5 km frame (~8 GB), one run.
    pub fn paper() -> WorkloadShape {
        WorkloadShape {
            step_bytes: crate::workload::PAPER_FRAME_BYTES,
            writers: 1,
        }
    }

    /// From physically-moved frame bytes and the run's volume scale.
    pub fn from_physical(frame_bytes: u64, volume_scale: f64) -> WorkloadShape {
        WorkloadShape {
            step_bytes: frame_bytes as f64 * volume_scale,
            writers: 1,
        }
    }

    /// Set the concurrent-ensemble-writer count.
    pub fn with_writers(mut self, writers: usize) -> WorkloadShape {
        self.writers = writers.max(1);
        self
    }
}

/// Single-thread codec throughput/ratio table the codec decision scores
/// against.  [`CodecProfile::paper_defaults`] pins the numbers measured
/// on the paper testbed's smooth meteorological fields, so planning (and
/// the `stormio plan` golden output) is deterministic;
/// [`CodecProfile::measured`] re-measures on this host.
#[derive(Debug, Clone)]
pub struct CodecProfile {
    entries: Vec<(Codec, CodecThroughput)>,
}

impl CodecProfile {
    /// Deterministic defaults: single-thread compress bandwidth (bytes/s)
    /// and compression ratio on smooth WRF-like f32 fields (fig 5/6
    /// orderings: zstd/zlib tightest, lz4/blosclz fastest).
    pub fn paper_defaults() -> CodecProfile {
        CodecProfile {
            entries: vec![
                (
                    Codec::BloscLz,
                    CodecThroughput {
                        compress_bps: 1.1e9,
                        ratio: 1.8,
                    },
                ),
                (
                    Codec::Lz4,
                    CodecThroughput {
                        compress_bps: 0.9e9,
                        ratio: 2.0,
                    },
                ),
                (
                    Codec::Zlib,
                    CodecThroughput {
                        compress_bps: 0.09e9,
                        ratio: 3.6,
                    },
                ),
                (
                    Codec::Zstd,
                    CodecThroughput {
                        compress_bps: 0.35e9,
                        ratio: 3.9,
                    },
                ),
            ],
        }
    }

    /// Measure every codec on `sample` (host-dependent; not used for the
    /// golden-checked plan output).
    pub fn measured(sample: &[u8]) -> Result<CodecProfile> {
        let mut entries = Vec::new();
        for codec in Codec::ALL {
            let t = crate::adios::operator::measure_throughput(
                sample,
                OperatorConfig::blosc(codec),
            )?;
            entries.push((codec, t));
        }
        Ok(CodecProfile { entries })
    }

    /// Inject a synthetic profile (planner unit tests).
    pub fn from_entries(entries: Vec<(Codec, CodecThroughput)>) -> CodecProfile {
        CodecProfile { entries }
    }

    pub fn entries(&self) -> &[(Codec, CodecThroughput)] {
        &self.entries
    }

    /// The profile's assumed compress throughput for `codec` (bytes/s);
    /// `None` for [`Codec::None`] or a codec not in the table.  The
    /// feedback loop compares this assumption against the engine's
    /// measured per-step throughput to detect codec lag (DESIGN.md §17).
    pub fn compress_bps(&self, codec: Codec) -> Option<f64> {
        self.entries
            .iter()
            .find(|(c, _)| *c == codec)
            .map(|(_, t)| t.compress_bps)
    }

    /// Serialize the profile for `stormio plan --measure-out`: one JSON
    /// object keyed by codec name.  Round-trips through
    /// [`CodecProfile::from_json`] so one microbenchmark run can seed
    /// many plan invocations on the same host.
    pub fn to_json(&self) -> String {
        let body: Vec<String> = self
            .entries
            .iter()
            .map(|(c, t)| {
                format!(
                    "  \"{}\": {{\"compress_bps\": {:.6e}, \"ratio\": {:.6}}}",
                    c.name(),
                    t.compress_bps,
                    t.ratio
                )
            })
            .collect();
        format!("{{\n{}\n}}\n", body.join(",\n"))
    }

    /// Parse a profile written by [`CodecProfile::to_json`]
    /// (`stormio plan --measure-in`).
    pub fn from_json(text: &str) -> Result<CodecProfile> {
        fn num_after(line: &str, key: &str) -> Option<f64> {
            let i = line.find(key)? + key.len();
            let rest = line[i..].trim_start_matches(|c: char| c == ':' || c == ' ');
            let end = rest
                .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
                .unwrap_or(rest.len());
            rest[..end].parse().ok()
        }
        let mut entries = Vec::new();
        for line in text.lines() {
            let Some(rest) = line.trim_start().strip_prefix('"') else {
                continue;
            };
            let Some(end) = rest.find('"') else { continue };
            let codec = Codec::parse(&rest[..end])?;
            match (
                num_after(line, "\"compress_bps\""),
                num_after(line, "\"ratio\""),
            ) {
                (Some(compress_bps), Some(ratio)) => {
                    entries.push((codec, CodecThroughput { compress_bps, ratio }))
                }
                _ => {
                    return Err(crate::Error::config(format!(
                        "codec profile entry missing compress_bps/ratio: {line}"
                    )))
                }
            }
        }
        if entries.is_empty() {
            return Err(crate::Error::config(
                "codec profile JSON has no codec entries",
            ));
        }
        Ok(CodecProfile { entries })
    }

    /// Scale every codec's compress throughput by `frac` (clamped to
    /// `(0, 1]`: the feedback loop only degrades the model).  Ratios are
    /// data properties, not host properties, and stay put.
    pub fn scaled(&self, frac: f64) -> CodecProfile {
        let f = if frac.is_finite() {
            frac.clamp(1e-6, 1.0)
        } else {
            1.0
        };
        CodecProfile {
            entries: self
                .entries
                .iter()
                .map(|(c, t)| {
                    (
                        *c,
                        CodecThroughput {
                            compress_bps: t.compress_bps * f,
                            ratio: t.ratio,
                        },
                    )
                })
                .collect(),
        }
    }
}

/// Predicted virtual costs of the resolved plan (provenance for
/// [`BenchReport`] and `stormio plan`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanCosts {
    /// Application-perceived virtual seconds per step.
    pub t_write: f64,
    /// Virtual seconds until the step is durable on the final target.
    pub t_durable: f64,
    /// Virtual seconds from the step leaving the app to the first
    /// analysis read completing ([`CostModel::time_to_first_analysis`];
    /// ~`t_write` for streaming engines).
    pub time_to_first_analysis: f64,
    /// Fan-out vs funnel-relay score for the streaming data plane
    /// (1.0 for file engines).
    pub fanout_advantage: f64,
    /// Predicted stored bytes per step after the chosen codec.
    pub stored_bytes: f64,
}

/// One per-consumer placement entry of a streaming plan.
#[derive(Debug, Clone)]
pub struct ConsumerPlan {
    pub address: String,
    /// Estimated wire bytes per step shipped to this consumer.  At plan
    /// time a full-step subscription is assumed; once the run is live,
    /// the feedback loop substitutes each consumer's *measured* cropped
    /// egress fraction ([`Planner::with_consumer_fractions`]) so replans
    /// score the subscriptions actually in force (DESIGN.md §17).
    pub est_bytes: f64,
}

/// The fully-resolved I/O plan: one typed decision record carrying every
/// engine knob from the namelist/XML/planner to the engines.
#[derive(Debug, Clone)]
pub struct IoPlan {
    pub engine: EngineKind,
    pub aggs_per_node: Decision<usize>,
    pub codec: Decision<Codec>,
    pub target: Decision<Target>,
    pub data_plane: Decision<DataPlane>,
    /// Codec + shuffle/lossy template the engines apply.
    pub operator: OperatorConfig,
    /// SST consumer placement (one lane per aggregator per consumer).
    pub consumers: Vec<ConsumerPlan>,
    pub live_publish: bool,
    pub frames_per_outfile: usize,
    pub pack_threads: usize,
    pub async_io: bool,
    /// Object-space retention (`adios2_object_retain_steps`): keep only
    /// the newest N committed steps, GCing older step objects after each
    /// commit.  `None` retains everything; file targets ignore it.  A GC
    /// policy rather than a planner decision, so it is deliberately not
    /// part of the rendered decision table.
    pub object_retain_steps: Option<usize>,
    /// Run the wire v4 consumer service broker on rank 0 so consumers
    /// can attach mid-stream (`adios2_sst_broker` / `Broker`, DESIGN.md
    /// §15).  With a broker the consumer set is dynamic, so an SST plan
    /// may open with zero pre-wired addresses.  A service toggle rather
    /// than a planner decision — deliberately not in the rendered table.
    pub broker: bool,
    /// Lane hello/subscription handshake timeout override in seconds
    /// (`adios2_sst_hello_timeout` / `HelloTimeout`); `None` = engine
    /// default.  Not rendered.
    pub sst_hello_timeout: Option<u64>,
    /// Lane-count sanity cap override (`adios2_sst_max_lanes` /
    /// `MaxLanes`); `None` = engine default.  Not rendered.
    pub sst_max_lanes: Option<u32>,
    /// Relay-tree branching (`adios2_relay_fanout` / `RelayFanout`,
    /// DESIGN.md §16): leaves per relay node; `0` = direct lanes.
    /// `None` when the knob is unset everywhere — the decision table
    /// then renders no relay row, keeping pre-relay plans byte-stable.
    pub relay_fanout: Option<Decision<usize>>,
    pub predicted: PlanCosts,
}

impl IoPlan {
    pub fn addresses(&self) -> Vec<String> {
        self.consumers.iter().map(|c| c.address.clone()).collect()
    }

    /// True when the plan publishes at burst-buffer durability (the
    /// "follow the drain" mode, DESIGN.md §11).
    pub fn bb_live(&self) -> bool {
        self.live_publish && matches!(self.target.value, Target::BurstBuffer { drain: true })
    }

    /// Relay nodes implied by the resolved branching: `ceil(consumers /
    /// fanout)`; zero with direct lanes (fanout 0 or knob unset).
    pub fn relay_nodes(&self) -> usize {
        match self.relay_fanout {
            Some(d) if d.value > 0 => {
                let n = self.consumers.len();
                (n + d.value - 1) / d.value
            }
            _ => 0,
        }
    }

    fn target_name(&self) -> &'static str {
        match self.target.value {
            Target::Pfs => "pfs",
            Target::BurstBuffer { drain: true } => "burstbuffer+drain",
            Target::BurstBuffer { drain: false } => "burstbuffer",
            Target::Object => "object",
        }
    }

    fn engine_name(&self) -> &'static str {
        match self.engine {
            EngineKind::Bp4 => "BP4",
            EngineKind::Sst => "SST",
            EngineKind::Null => "null",
        }
    }

    fn plane_name(&self) -> &'static str {
        match self.data_plane.value {
            DataPlane::Lanes => "lanes",
            DataPlane::Funnel => "funnel",
        }
    }

    /// One-line provenance summary for run reports.
    pub fn summary_line(&self) -> String {
        format!(
            "io plan: engine {} · aggs/node {} [{}] · codec {} [{}] · target {} [{}] · \
             data plane {} [{}] · predicted write {:.3}s",
            self.engine_name(),
            self.aggs_per_node.value,
            self.aggs_per_node.source,
            self.codec.value.name(),
            self.codec.source,
            self.target_name(),
            self.target.source,
            self.plane_name(),
            self.data_plane.source,
            self.predicted.t_write,
        )
    }

    /// The `stormio plan` decision table.  The format is deliberately
    /// plain and stable: CI diffs it against a checked-in golden snapshot
    /// so planner regressions are visible in review.
    pub fn render(&self, io_name: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("io plan: {io_name}\n"));
        out.push_str(&format!("  {:<22}= {}\n", "engine", self.engine_name()));
        out.push_str(&format!(
            "  {:<22}= {:<18} [{}]\n",
            "aggregators_per_node", self.aggs_per_node.value, self.aggs_per_node.source
        ));
        out.push_str(&format!(
            "  {:<22}= {:<18} [{}]\n",
            "codec",
            self.codec.value.name(),
            self.codec.source
        ));
        out.push_str(&format!(
            "  {:<22}= {:<18} [{}]\n",
            "target",
            self.target_name(),
            self.target.source
        ));
        out.push_str(&format!(
            "  {:<22}= {:<18} [{}]\n",
            "data_plane",
            self.plane_name(),
            self.data_plane.source
        ));
        out.push_str(&format!(
            "  {:<22}= {}\n",
            "live_publish", self.live_publish
        ));
        out.push_str(&format!(
            "  {:<22}= {}\n",
            "frames_per_outfile", self.frames_per_outfile
        ));
        out.push_str(&format!(
            "  {:<22}= {}\n",
            "consumers",
            self.consumers.len()
        ));
        // Relay rows appear only when the knob was set somewhere: plans
        // from pre-relay configs render byte-identically.
        if let Some(rf) = &self.relay_fanout {
            out.push_str(&format!(
                "  {:<22}= {:<18} [{}]\n",
                "relay_fanout", rf.value, rf.source
            ));
            out.push_str(&format!(
                "  {:<22}= {}\n",
                "relay_nodes",
                self.relay_nodes()
            ));
        }
        out.push_str("predicted (virtual, CONUS-scale):\n");
        out.push_str(&format!(
            "  {:<22}= {:.3}\n",
            "step_gb",
            self.predicted.stored_bytes.max(0.0) / 1e9
        ));
        out.push_str(&format!(
            "  {:<22}= {:.3} s\n",
            "t_write", self.predicted.t_write
        ));
        out.push_str(&format!(
            "  {:<22}= {:.3} s\n",
            "t_durable", self.predicted.t_durable
        ));
        out.push_str(&format!(
            "  {:<22}= {:.3} s\n",
            "time_to_first_analysis", self.predicted.time_to_first_analysis
        ));
        out.push_str(&format!(
            "  {:<22}= {:.2}\n",
            "fanout_advantage", self.predicted.fanout_advantage
        ));
        out
    }

    /// Record the plan's chosen values and predicted times as provenance
    /// fields of a bench report (`BENCH_*.json`).
    pub fn stamp(&self, r: &mut BenchReport) {
        r.text("plan_engine", self.engine_name());
        r.int("plan_aggs_per_node", self.aggs_per_node.value as u64);
        r.text("plan_aggs_source", &self.aggs_per_node.source.to_string());
        r.text("plan_codec", self.codec.value.name());
        r.text("plan_codec_source", &self.codec.source.to_string());
        r.text("plan_target", self.target_name());
        r.text("plan_target_source", &self.target.source.to_string());
        r.text("plan_data_plane", self.plane_name());
        r.text("plan_data_plane_source", &self.data_plane.source.to_string());
        r.int("plan_consumers", self.consumers.len() as u64);
        r.num("plan_t_write", self.predicted.t_write);
        r.num("plan_t_durable", self.predicted.t_durable);
        r.num(
            "plan_time_to_first_analysis",
            self.predicted.time_to_first_analysis,
        );
        r.num("plan_fanout_advantage", self.predicted.fanout_advantage);
        if let Some(rf) = &self.relay_fanout {
            r.int("plan_relay_fanout", rf.value as u64);
            r.text("plan_relay_fanout_source", &rf.source.to_string());
            r.int("plan_relay_nodes", self.relay_nodes() as u64);
        }
    }
}

/// The planner: scores intents against the virtual testbed.
#[derive(Debug, Clone)]
pub struct Planner {
    pub cost: CostModel,
    pub shape: WorkloadShape,
    pub codecs: CodecProfile,
    /// Live per-consumer egress fractions (wire bytes / stored step
    /// bytes) from the fan-out ledger, indexed like the intent's address
    /// list.  Empty = plan-time default (every consumer full-step).
    /// Filled by the feedback loop so `fanout_advantage` and the egress
    /// prediction score the *cropped* subscriptions actually in force
    /// (DESIGN.md §17).
    pub consumer_fracs: Vec<f64>,
    /// Score the target sweep on steady-state cadence (a step cannot
    /// retire faster than its durable landing) instead of the app-
    /// perceived basis.  Set by [`Planner::with_measured`] when a
    /// measured drain/PFS deficit means the pipeline is no longer hiding
    /// the drain; always false on the open-loop path.
    pub durable_cadence: bool,
}

impl Planner {
    pub fn new(cost: CostModel, shape: WorkloadShape) -> Planner {
        Planner {
            cost,
            shape,
            codecs: CodecProfile::paper_defaults(),
            consumer_fracs: Vec::new(),
            durable_cadence: false,
        }
    }

    /// Override the codec throughput table (tests / `--measure`).
    pub fn with_codec_profile(mut self, codecs: CodecProfile) -> Planner {
        self.codecs = codecs;
        self
    }

    /// Substitute live per-consumer egress fractions (cropped
    /// [`crate::adios::Subscription`]s) into the fan-out scoring.
    pub fn with_consumer_fractions(mut self, fracs: Vec<f64>) -> Planner {
        self.consumer_fracs = fracs;
        self
    }

    /// The cropped-egress fraction of consumer `i` (1.0 = full step).
    fn consumer_frac(&self, i: usize) -> f64 {
        match self.consumer_fracs.get(i) {
            Some(f) if f.is_finite() => f.clamp(1e-6, 1.0),
            _ => 1.0,
        }
    }

    /// Substitute a measured testbed profile (DESIGN.md §17): bandwidth
    /// fractions degrade the cost model, the measured codec fraction
    /// scales the throughput table, and any drain/PFS deficit switches
    /// the target sweep to the steady-state cadence basis.  A nominal
    /// profile returns a planner that plans bit-identically to `self`.
    pub fn with_measured(&self, measured: &crate::sim::MeasuredProfile) -> Planner {
        let m = measured.clamped();
        Planner {
            cost: self.cost.with_measured(&m),
            shape: self.shape,
            codecs: self.codecs.scaled(m.compress_frac),
            consumer_fracs: self.consumer_fracs.clone(),
            durable_cadence: self.durable_cadence
                || m.drain_bw_frac < 0.999
                || m.pfs_bw_frac < 0.999,
        }
    }

    /// Re-resolve the intent's `'auto'` knobs under the *measured*
    /// testbed.  Explicit (namelist/XML-pinned) knobs pass through with
    /// their original provenance — the feedback loop only ever moves
    /// knobs the user delegated with `'auto'` (DESIGN.md §17).
    pub fn replan(
        &self,
        engine: EngineKind,
        intent: &IoIntent,
        measured: &crate::sim::MeasuredProfile,
    ) -> Result<IoPlan> {
        self.with_measured(measured).plan(engine, intent)
    }

    /// Aggregators-per-node candidates: the divisors of `ranks_per_node`
    /// (every candidate yields equal-sized member groups).
    pub fn agg_candidates(&self) -> Vec<usize> {
        let rpn = self.cost.hw.ranks_per_node.max(1);
        (1..=rpn).filter(|c| rpn % c == 0).collect()
    }

    /// MDS create amortization: sub-file creates are paid once per
    /// outfile, spread over the frames it holds (single-file mode writes
    /// many steps into one outfile).
    fn frames_per_file(&self, frames_per_outfile: usize) -> f64 {
        if frames_per_outfile == 0 {
            16.0
        } else {
            frames_per_outfile as f64
        }
    }

    /// Chain-gather + landing time of `stored` bytes through `naggs`
    /// aggregators onto `target`.  The object space is charged its own
    /// put path (per-writer pipeline capped by a fair share of the
    /// aggregate ingest, [`CostModel::t_obj_put`] with the shape's
    /// ensemble-writer count) instead of the file-store model.
    fn t_landing(&self, stored: f64, naggs: usize, target: Target) -> f64 {
        match target {
            Target::Object => {
                self.cost.t_chain_gather(stored, naggs)
                    + self.cost.t_obj_put(stored, self.shape.writers)
            }
            _ => {
                let bb = matches!(target, Target::BurstBuffer { .. });
                self.cost.t_bp4_perceived(stored, naggs, bb)
            }
        }
    }

    /// Metadata charge of one step on `target`: sub-file + index creates
    /// through the MDS storm formula for the file targets, one index
    /// create plus flat per-key inserts (one object per producer block)
    /// for the object space.
    fn t_metadata(&self, naggs: usize, target: Target, frames_per_outfile: usize) -> f64 {
        match target {
            Target::Object => {
                // ~2 history vars' worth of per-rank blocks: a flat,
                // sub-percent correction, not a decision driver.
                self.cost.t_obj_md(self.cost.hw.ranks().max(1) * 2)
                    + self.cost.t_mds_creates(1) / self.frames_per_file(frames_per_outfile)
            }
            _ => self.cost.t_mds_creates(naggs + 1) / self.frames_per_file(frames_per_outfile),
        }
    }

    /// Per-step virtual cost of a BP4 write with `aggs_per_node`
    /// aggregators landing `stored` bytes on `target`.
    pub fn score_aggregators(
        &self,
        aggs_per_node: usize,
        stored: f64,
        target: Target,
        frames_per_outfile: usize,
    ) -> f64 {
        let naggs = aggs_per_node * self.cost.hw.nodes.max(1);
        self.t_landing(stored, naggs, target) + self.t_metadata(naggs, target, frames_per_outfile)
    }

    /// Sweep the aggregator candidates; returns (argmin, its score).
    pub fn choose_aggregators(
        &self,
        target: Target,
        frames_per_outfile: usize,
    ) -> (usize, f64) {
        let v = self.shape.step_bytes;
        let mut best = (1usize, f64::INFINITY);
        for c in self.agg_candidates() {
            let s = self.score_aggregators(c, v, target, frames_per_outfile);
            if s < best.1 {
                best = (c, s);
            }
        }
        best
    }

    /// Auto target at the shape's own ensemble-writer count.
    pub fn choose_target(&self, frames_per_outfile: usize) -> Target {
        self.choose_target_for(frames_per_outfile, self.shape.writers)
    }

    /// The three-way target sweep (DESIGN.md §13).
    ///
    /// A lone run (`writers == 1`) is scored on the app-perceived basis —
    /// the paper's fig 4/7 regime, where the NVMe burst buffer wins at
    /// CONUS scale and the object space's cross-run isolation buys
    /// nothing.  With `writers > 1` concurrent ensemble members sharing
    /// the final store, the basis switches to time-to-durable: direct PFS
    /// writes *and* the burst-buffer drain pay the cross-run seek
    /// contention factor, while each member puts into the object space
    /// independently (capped only by a fair share of its aggregate
    /// ingest).
    pub fn choose_target_for(&self, frames_per_outfile: usize, writers: usize) -> Target {
        let p = if writers == self.shape.writers {
            self.clone()
        } else {
            let mut p = self.clone();
            p.shape.writers = writers.max(1);
            p
        };
        let (_, pfs) = p.choose_aggregators(Target::Pfs, frames_per_outfile);
        let (_, bb) =
            p.choose_aggregators(Target::BurstBuffer { drain: true }, frames_per_outfile);
        if p.shape.writers <= 1 {
            if p.durable_cadence {
                // Measured-feedback regime (DESIGN.md §17): the drain is
                // no longer hidden, so a step cannot retire faster than
                // its durable landing.  Score every target on that
                // cadence — the BB's perceived NVMe landing is floored by
                // its (degraded) drain, direct PFS is already durable,
                // and the object space (its own NVMe-backed ingest) joins
                // the sweep as the contention-free escape hatch.
                let nodes = p.cost.hw.nodes.max(1);
                let bb_c = bb.max(p.cost.t_bb_drain(p.shape.step_bytes, nodes));
                let (_, obj) = p.choose_aggregators(Target::Object, frames_per_outfile);
                return if obj <= pfs && obj <= bb_c {
                    Target::Object
                } else if bb_c < pfs {
                    Target::BurstBuffer { drain: true }
                } else {
                    Target::Pfs
                };
            }
            return if bb < pfs {
                Target::BurstBuffer { drain: true }
            } else {
                Target::Pfs
            };
        }
        let c = p.cost.cross_run_contention(p.shape.writers);
        let pfs_durable = pfs * c;
        let bb_durable = bb
            + p.cost.t_bb_drain(p.shape.step_bytes, p.cost.hw.nodes.max(1)) * c;
        let (_, obj) = p.choose_aggregators(Target::Object, frames_per_outfile);
        if obj <= pfs_durable && obj <= bb_durable {
            Target::Object
        } else if bb_durable < pfs_durable {
            Target::BurstBuffer { drain: true }
        } else {
            Target::Pfs
        }
    }

    /// Per-step perceived cost of writing through `codec` (throughput
    /// `prof`): per-rank compression + chain + landing write of the
    /// compressed volume.
    pub fn score_codec(
        &self,
        prof: Option<CodecThroughput>,
        aggs_per_node: usize,
        target: Target,
    ) -> f64 {
        let v = self.shape.step_bytes;
        let naggs = aggs_per_node * self.cost.hw.nodes.max(1);
        match prof {
            None => self.t_landing(v, naggs, target),
            Some(p) => {
                let stored = v / p.ratio.max(1.0);
                self.cost.t_compress(v, p.compress_bps) + self.t_landing(stored, naggs, target)
            }
        }
    }

    /// Auto codec: the argmin over `{none} ∪ codecs`.  Falls back to
    /// `none` when no codec's compression throughput keeps up with the
    /// landing store (e.g. NVMe faster than the codec's per-rank rate).
    pub fn choose_codec(&self, aggs_per_node: usize, target: Target) -> Codec {
        let mut best = (Codec::None, self.score_codec(None, aggs_per_node, target));
        for (codec, prof) in self.codecs.entries() {
            let s = self.score_codec(Some(*prof), aggs_per_node, target);
            if s < best.1 {
                best = (*codec, s);
            }
        }
        best.0
    }

    /// Per-step perceived cost of streaming through `codec` over the SST
    /// data plane: per-rank compression + chain to the lane aggregators +
    /// wire egress of one (compressed) copy per consumer.  The streaming
    /// twin of [`Planner::score_codec`] — SST never pays a file landing,
    /// so its `'auto'` codec choice must weigh the NIC, not the store.
    pub fn score_codec_stream(
        &self,
        prof: Option<CodecThroughput>,
        lanes: usize,
        consumers: usize,
    ) -> f64 {
        let v = self.shape.step_bytes;
        let (t_comp, stored) = match prof {
            None => (0.0, v),
            Some(p) => (self.cost.t_compress(v, p.compress_bps), v / p.ratio.max(1.0)),
        };
        let per_consumer = vec![stored; consumers.max(1)];
        t_comp
            + self.cost.t_chain_gather(stored, lanes.max(1))
            + self.cost.t_stream_egress(&per_consumer, lanes)
    }

    /// Auto codec for an SST plan: argmin of [`Planner::score_codec_stream`]
    /// over `{none} ∪ codecs`.
    pub fn choose_codec_stream(&self, lanes: usize, consumers: usize) -> Codec {
        let mut best = (Codec::None, self.score_codec_stream(None, lanes, consumers));
        for (codec, prof) in self.codecs.entries() {
            let s = self.score_codec_stream(Some(*prof), lanes, consumers);
            if s < best.1 {
                best = (*codec, s);
            }
        }
        best.0
    }

    /// Auto data plane for a fan-out of `per_consumer_bytes` over
    /// `lanes`: parallel lanes when the fan-out beats the rank-0
    /// funnel-and-relay, the funnel otherwise.
    pub fn choose_data_plane(&self, stored: f64, per_consumer: &[f64], lanes: usize) -> DataPlane {
        if self.cost.fanout_advantage(stored, per_consumer, lanes) >= 1.0 {
            DataPlane::Lanes
        } else {
            DataPlane::Funnel
        }
    }

    /// Auto relay branching (DESIGN.md §16): a 2-level tree needs enough
    /// leaves to amortize its extra hop — below 8 consumers direct lanes
    /// always win, above that `ceil(sqrt(n))` balances producer streams
    /// against per-relay load, but only if the tree actually scores
    /// better than direct on this shape
    /// ([`CostModel::fanout_advantage_tree`]).  Returns the branching
    /// factor, 0 for direct lanes.
    pub fn choose_relay_fanout(
        &self,
        stored: f64,
        per_consumer: &[f64],
        lanes: usize,
    ) -> usize {
        let n = per_consumer.len();
        if n < 8 {
            return 0;
        }
        let b = (n as f64).sqrt().ceil() as usize;
        let relays = (n + b - 1) / b;
        if self
            .cost
            .fanout_advantage_tree(stored, per_consumer, lanes, relays)
            > 1.0
        {
            b
        } else {
            0
        }
    }

    /// Resolve every knob of `intent` for `engine` into an [`IoPlan`].
    pub fn plan(&self, engine: EngineKind, intent: &IoIntent) -> Result<IoPlan> {
        // An explicit `adios2_ensemble_writers` overrides the shape's
        // writer count so every downstream score (target sweep, codec,
        // prediction) sees the same contention regime.
        let writers = intent.ensemble_writers.unwrap_or(self.shape.writers).max(1);
        if writers != self.shape.writers {
            let mut p = self.clone();
            p.shape.writers = writers;
            return p.plan(engine, intent);
        }
        let frames_per_outfile = intent.frames_per_outfile.unwrap_or(1);
        let live_publish = intent.live_publish.unwrap_or(false);

        // Target first (SST never touches storage: pin PFS there).  When
        // the planner picks the burst buffer, an explicit standalone
        // `adios2_drain` still decides whether frames drain to the PFS
        // (the paper's §V-B ran drain-disabled); absent a preference the
        // auto choice drains.
        let target = if engine == EngineKind::Sst {
            Decision::new(Target::Pfs, DecisionSource::Default)
        } else {
            decide(
                intent.target,
                || match self.choose_target(frames_per_outfile) {
                    Target::BurstBuffer { .. } => Target::BurstBuffer {
                        drain: intent.drain.unwrap_or(true),
                    },
                    t => t,
                },
                Target::Pfs,
            )
        };
        let aggs = decide(
            intent.aggregators,
            || self.choose_aggregators(target.value, frames_per_outfile).0,
            1,
        );
        if aggs.value < 1 || aggs.value > self.cost.hw.ranks_per_node {
            return Err(Error::config(format!(
                "aggregators_per_node {} out of range 1..={}",
                aggs.value, self.cost.hw.ranks_per_node
            )));
        }
        let codec = decide(
            intent.codec,
            || {
                if engine == EngineKind::Sst {
                    self.choose_codec_stream(
                        aggs.value * self.cost.hw.nodes.max(1),
                        intent.addresses.len(),
                    )
                } else {
                    self.choose_codec(aggs.value, target.value)
                }
            },
            Codec::None,
        );
        let stored = match self
            .codecs
            .entries()
            .iter()
            .find(|(c, _)| *c == codec.value)
        {
            Some((_, p)) => self.shape.step_bytes / p.ratio.max(1.0),
            None => self.shape.step_bytes,
        };

        let consumers: Vec<ConsumerPlan> = intent
            .addresses
            .iter()
            .enumerate()
            .map(|(i, a)| ConsumerPlan {
                address: a.clone(),
                est_bytes: stored * self.consumer_frac(i),
            })
            .collect();
        let broker = intent.sst_broker.unwrap_or(false);
        if engine == EngineKind::Sst && consumers.is_empty() && !broker {
            return Err(Error::config("SST io needs an Address parameter"));
        }
        let per_consumer: Vec<f64> = consumers.iter().map(|c| c.est_bytes).collect();
        let lanes = aggs.value * self.cost.hw.nodes.max(1);
        // Score the fan-out against one full-step consumer when no
        // addresses are configured (file engines).
        let solo = [stored];
        let fan_consumers: &[f64] = if per_consumer.is_empty() {
            &solo
        } else {
            &per_consumer
        };
        let data_plane = decide(
            intent.data_plane,
            || self.choose_data_plane(stored, fan_consumers, lanes),
            DataPlane::Lanes,
        );

        // Relay tree (DESIGN.md §16): resolved only when the knob was
        // actually set — pre-relay configs keep their exact plan output.
        let relay_fanout = if intent.relay_fanout.setting.is_unset() {
            None
        } else {
            Some(decide(
                intent.relay_fanout,
                || self.choose_relay_fanout(stored, &per_consumer, lanes),
                0,
            ))
        };
        let relay_nodes = match relay_fanout {
            Some(d) if d.value > 0 => {
                (consumers.len() + d.value - 1) / d.value
            }
            _ => 0,
        };

        // Operator: keep the XML shuffle/lossy template when it already
        // carries the chosen codec; otherwise the blosc default stack.
        let operator = match intent.operator_base {
            Some(op) if op.codec == codec.value => op,
            _ => OperatorConfig::blosc(codec.value),
        };

        let predicted = self.predict(
            engine.clone(),
            aggs.value,
            codec.value,
            target.value,
            stored,
            fan_consumers,
            lanes,
            relay_nodes,
            frames_per_outfile,
            live_publish,
        );

        Ok(IoPlan {
            engine,
            aggs_per_node: aggs,
            codec,
            target,
            data_plane,
            operator,
            consumers,
            live_publish,
            frames_per_outfile,
            pack_threads: intent.pack_threads.unwrap_or(0),
            async_io: intent.async_io.unwrap_or(true),
            object_retain_steps: intent.object_retain_steps,
            broker,
            sst_hello_timeout: intent.sst_hello_timeout,
            sst_max_lanes: intent.sst_max_lanes,
            relay_fanout,
            predicted,
        })
    }

    /// Compose the predicted per-step virtual costs of a resolved plan.
    #[allow(clippy::too_many_arguments)]
    fn predict(
        &self,
        engine: EngineKind,
        aggs_per_node: usize,
        codec: Codec,
        target: Target,
        stored: f64,
        per_consumer: &[f64],
        lanes: usize,
        relay_nodes: usize,
        frames_per_outfile: usize,
        live_publish: bool,
    ) -> PlanCosts {
        let cm = &self.cost;
        let v = self.shape.step_bytes;
        let naggs = aggs_per_node * cm.hw.nodes.max(1);
        let t_comp = match self.codecs.entries().iter().find(|(c, _)| *c == codec) {
            Some((_, p)) => cm.t_compress(v, p.compress_bps),
            None => 0.0,
        };
        match engine {
            EngineKind::Bp4 => {
                let t_write = t_comp
                    + self.t_landing(stored, naggs, target)
                    + self.t_metadata(naggs, target, frames_per_outfile);
                let t_drain = match target {
                    Target::BurstBuffer { drain: true } => {
                        cm.t_bb_drain(stored, cm.hw.nodes.max(1))
                    }
                    _ => 0.0,
                };
                let bb_follow = live_publish && matches!(target, Target::BurstBuffer { drain: true });
                PlanCosts {
                    t_write,
                    t_durable: t_write + t_drain,
                    time_to_first_analysis: cm.time_to_first_analysis(stored, bb_follow),
                    fanout_advantage: 1.0,
                    stored_bytes: stored,
                }
            }
            EngineKind::Sst => {
                let chain = cm.t_chain_gather(stored, lanes);
                if relay_nodes > 0 {
                    // Under a relay tree the producer ships one stream
                    // per relay (each the size of its widest round-robin
                    // leaf) instead of one per consumer — the egress
                    // relief the tree buys.  Leaves see their data one
                    // hop later: the slowest relay's receive + re-serve
                    // lands on time_to_first_analysis, not on the
                    // producer's t_write.
                    let mut relay_streams = vec![0.0f64; relay_nodes];
                    for (i, b) in per_consumer.iter().enumerate() {
                        let g = i % relay_nodes;
                        relay_streams[g] = relay_streams[g].max(*b);
                    }
                    let t_write =
                        t_comp + chain + cm.t_stream_egress(&relay_streams, lanes);
                    let slowest_hop = (0..relay_nodes)
                        .map(|g| {
                            let leaves: Vec<f64> = per_consumer
                                .iter()
                                .enumerate()
                                .filter(|(i, _)| i % relay_nodes == g)
                                .map(|(_, b)| *b)
                                .collect();
                            cm.t_relay_hop(relay_streams[g], &leaves)
                        })
                        .fold(0.0f64, f64::max);
                    PlanCosts {
                        t_write,
                        t_durable: t_write,
                        time_to_first_analysis: t_write + slowest_hop,
                        fanout_advantage: cm.fanout_advantage_tree(
                            stored,
                            per_consumer,
                            lanes,
                            relay_nodes,
                        ),
                        stored_bytes: stored,
                    }
                } else {
                    let egress = cm.t_stream_egress(per_consumer, lanes);
                    let t_write = t_comp + chain + egress;
                    PlanCosts {
                        t_write,
                        t_durable: t_write,
                        time_to_first_analysis: t_write,
                        fanout_advantage: cm.fanout_advantage(stored, per_consumer, lanes),
                        stored_bytes: stored,
                    }
                }
            }
            EngineKind::Null => PlanCosts {
                fanout_advantage: 1.0,
                stored_bytes: 0.0,
                ..PlanCosts::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namelist::Namelist;
    use crate::sim::HardwareSpec;

    fn planner(nodes: usize) -> Planner {
        Planner::new(
            CostModel::new(HardwareSpec::paper_testbed(nodes)),
            WorkloadShape::paper(),
        )
    }

    fn intent(body: &str) -> IoIntent {
        let nl = Namelist::parse(&format!("&time_control\n{body}\n/\n")).unwrap();
        IoIntent::from_time_control(nl.group("time_control").unwrap()).unwrap()
    }

    #[test]
    fn codec_profile_json_round_trips() {
        let p = CodecProfile::paper_defaults();
        let q = CodecProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p.entries().len(), q.entries().len());
        for ((c1, t1), (c2, t2)) in p.entries().iter().zip(q.entries()) {
            assert_eq!(c1, c2);
            assert!((t1.compress_bps - t2.compress_bps).abs() <= 1e-3 * t1.compress_bps);
            assert!((t1.ratio - t2.ratio).abs() < 1e-6);
        }
        // No entries / garbage is an error, not an empty profile.
        assert!(CodecProfile::from_json("{}").is_err());
        assert!(CodecProfile::from_json("\"zstd\": {\"ratio\": 2}").is_err());
    }

    #[test]
    fn aggregator_sweep_picks_cost_model_argmin() {
        for nodes in [1usize, 8] {
            let p = planner(nodes);
            let (best, score) = p.choose_aggregators(Target::Pfs, 1);
            // Brute-force argmin over the same candidate set.
            for c in p.agg_candidates() {
                let s = p.score_aggregators(c, p.shape.step_bytes, Target::Pfs, 1);
                assert!(
                    score <= s + 1e-12,
                    "{nodes} nodes: sweep missed candidate {c} ({s} < {score})"
                );
            }
            assert!(p.agg_candidates().contains(&best));
            // Paper fig 4 shape: a single node needs several streams to
            // saturate BeeGFS; at 8 nodes the full 36/node thrashes.
            if nodes == 1 {
                assert!(best > 1, "1 node: one stream cannot saturate the PFS");
            } else {
                assert!(best < 36, "8 nodes: 288 streams must not be optimal");
            }
        }
    }

    #[test]
    fn auto_aggregators_resolve_via_sweep() {
        let p = planner(8);
        let plan = p
            .plan(EngineKind::Bp4, &intent("adios2_num_aggregators = 'auto',"))
            .unwrap();
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Auto);
        assert_eq!(
            plan.aggs_per_node.value,
            p.choose_aggregators(Target::Pfs, 1).0
        );
        // Explicit value passes through untouched.
        let plan = p
            .plan(EngineKind::Bp4, &intent("adios2_num_aggregators = 36,"))
            .unwrap();
        assert_eq!(plan.aggs_per_node.value, 36);
        assert_eq!(plan.aggs_per_node.source, DecisionSource::Namelist);
    }

    #[test]
    fn funnel_chosen_when_fanout_advantage_below_one() {
        // Latency-dominated shape: a tiny step fanned out to many
        // consumers through one lane — the per-consumer message latency
        // of the direct fan-out exceeds the relay's serial gather.
        let p = Planner::new(
            CostModel::new(HardwareSpec::paper_testbed(1)),
            WorkloadShape {
                step_bytes: 1.0e4,
                writers: 1,
            },
        );
        let per_consumer = vec![1.0e4; 64];
        let adv = p.cost.fanout_advantage(1.0e4, &per_consumer, 1);
        assert!(adv < 1.0, "shape must be latency-dominated: {adv}");
        assert_eq!(p.choose_data_plane(1.0e4, &per_consumer, 1), DataPlane::Funnel);
        // A CONUS-scale fan-out picks the parallel lanes.
        let p8 = planner(8);
        let v = p8.shape.step_bytes;
        assert_eq!(
            p8.choose_data_plane(v, &[v, v, v], 8),
            DataPlane::Lanes
        );
    }

    #[test]
    fn codec_falls_back_to_none_when_throughput_cannot_keep_up() {
        // All codecs crawl at 1 MB/s: per-rank compression time dwarfs
        // what the NVMe landing saves, so 'auto' must pick none.
        let slow = CodecProfile::from_entries(
            Codec::ALL
                .iter()
                .map(|c| {
                    (
                        *c,
                        CodecThroughput {
                            compress_bps: 1.0e6,
                            ratio: 4.0,
                        },
                    )
                })
                .collect(),
        );
        let p = planner(8).with_codec_profile(slow);
        let bb = Target::BurstBuffer { drain: true };
        assert_eq!(p.choose_codec(1, bb), Codec::None);
        let plan = p
            .plan(
                EngineKind::Bp4,
                &intent("adios2_compression = 'auto',\n adios2_target = 'bb',\n adios2_drain = .true.,"),
            )
            .unwrap();
        assert_eq!(plan.codec.value, Codec::None);
        assert_eq!(plan.codec.source, DecisionSource::Auto);
        // With the real profile, compression wins on the slow PFS.
        let p = planner(8);
        assert_ne!(p.choose_codec(1, Target::Pfs), Codec::None);
    }

    #[test]
    fn auto_target_picks_burst_buffer_at_paper_scale() {
        let p = planner(8);
        assert_eq!(
            p.choose_target(1),
            Target::BurstBuffer { drain: true },
            "NVMe landing must beat the spinning PFS at CONUS scale"
        );
        let plan = p
            .plan(EngineKind::Bp4, &intent("adios2_target = 'auto',"))
            .unwrap();
        assert_eq!(plan.target.source, DecisionSource::Auto);
        assert!(matches!(
            plan.target.value,
            Target::BurstBuffer { drain: true }
        ));
        // Predicted durable time includes the background drain.
        assert!(plan.predicted.t_durable > plan.predicted.t_write);
    }

    #[test]
    fn three_way_sweep_prefers_object_for_ensembles() {
        let p = planner(8);
        // A lone run keeps the paper's answer: the burst buffer.
        assert_eq!(
            p.choose_target_for(1, 1),
            Target::BurstBuffer { drain: true }
        );
        // N members sharing one PFS: the contention-free object space
        // wins on time-to-durable, and keeps winning as N grows.
        for writers in [2usize, 4, 8, 16] {
            assert_eq!(
                p.choose_target_for(1, writers),
                Target::Object,
                "{writers} writers must resolve to the object space"
            );
        }
        // The resolved plan records the auto provenance and the object
        // target's durable-on-return semantics (no drain tail).
        let plan = p
            .plan(
                EngineKind::Bp4,
                &intent("adios2_target = 'auto',\n adios2_ensemble_writers = 8,"),
            )
            .unwrap();
        assert_eq!(plan.target.value, Target::Object);
        assert_eq!(plan.target.source, DecisionSource::Auto);
        assert_eq!(plan.target_name(), "object");
        assert!(plan.render("ens").contains("object"));
        assert!((plan.predicted.t_durable - plan.predicted.t_write).abs() < 1e-12);
        assert!(plan.predicted.t_write > 0.0);
    }

    #[test]
    fn explicit_object_target_passes_through() {
        let p = planner(8);
        let plan = p
            .plan(EngineKind::Bp4, &intent("adios2_target = 'object',"))
            .unwrap();
        assert_eq!(plan.target.value, Target::Object);
        assert_eq!(plan.target.source, DecisionSource::Namelist);
        assert!(!plan.bb_live());
        // Scoring an explicit object plan must use the object landing
        // model, not the PFS stream model: at one writer the put pipeline
        // (1.8 GB/s) beats the ~1 GB/s spinning PFS.
        let v = p.shape.step_bytes;
        let obj = p.score_aggregators(1, v, Target::Object, 1);
        let pfs = p.score_aggregators(1, v, Target::Pfs, 1);
        assert!(obj < pfs, "object landing {obj} must beat PFS {pfs}");
    }

    #[test]
    fn all_auto_plan_is_consistent_and_stampable() {
        let p = planner(8);
        let plan = p
            .plan(
                EngineKind::Bp4,
                &intent(
                    "adios2_num_aggregators = 'auto',\n adios2_compression = 'auto',\n \
                     adios2_target = 'auto',\n adios2_sst_data_plane = 'auto',",
                ),
            )
            .unwrap();
        assert!(plan.predicted.t_write > 0.0);
        assert!(plan.predicted.time_to_first_analysis > 0.0);
        let mut r = BenchReport::new("plan_test");
        plan.stamp(&mut r);
        let j = r.to_json();
        assert!(j.contains("\"plan_aggs_source\": \"auto\""));
        assert!(j.contains("\"plan_t_write\""));
        let table = plan.render("wrf_history");
        assert!(table.contains("aggregators_per_node"));
        assert!(table.contains("[auto]"));
        assert!(plan.summary_line().contains("io plan"));
    }

    #[test]
    fn sst_plan_requires_addresses_and_scores_fanout() {
        let p = planner(2);
        assert!(p.plan(EngineKind::Sst, &IoIntent::default()).is_err());
        let plan = p
            .plan(
                EngineKind::Sst,
                &intent("adios2_sst_address = '127.0.0.1:1, 127.0.0.1:2',"),
            )
            .unwrap();
        assert_eq!(plan.consumers.len(), 2);
        assert!(plan.predicted.fanout_advantage > 0.0);
        assert_eq!(plan.addresses(), vec!["127.0.0.1:1", "127.0.0.1:2"]);
    }

    #[test]
    fn broker_plan_allows_zero_prewired_consumers() {
        let p = planner(2);
        // With the service broker on, SST membership is dynamic: a plan
        // with no Address parameter is valid (consumers attach later).
        let plan = p
            .plan(EngineKind::Sst, &intent("adios2_sst_broker = .true.,"))
            .unwrap();
        assert!(plan.broker);
        assert!(plan.consumers.is_empty());
        // The service knobs ride through to the plan untouched.
        let plan = p
            .plan(
                EngineKind::Sst,
                &intent(
                    "adios2_sst_broker = .true.,\n \
                     adios2_sst_hello_timeout = 7,\n \
                     adios2_sst_max_lanes = 32,\n \
                     adios2_sst_address = '127.0.0.1:1',",
                ),
            )
            .unwrap();
        assert_eq!(plan.sst_hello_timeout, Some(7));
        assert_eq!(plan.sst_max_lanes, Some(32));
        // Broker off + no addresses is still the v3 config error.
        assert!(p.plan(EngineKind::Sst, &IoIntent::default()).is_err());
        // File plans default the service tier off.
        let bp = p.plan(EngineKind::Bp4, &IoIntent::default()).unwrap();
        assert!(!bp.broker && bp.sst_hello_timeout.is_none() && bp.sst_max_lanes.is_none());
    }

    #[test]
    fn sst_auto_codec_scores_egress_not_file_write() {
        let p = planner(8);
        // Consistency: the streaming choice is the argmin of the
        // streaming objective, never worse than sending uncompressed.
        let chosen = p.choose_codec_stream(8, 1);
        let prof = p
            .codecs
            .entries()
            .iter()
            .find(|(c, _)| *c == chosen)
            .map(|(_, t)| *t);
        assert!(
            p.score_codec_stream(prof, 8, 1) <= p.score_codec_stream(None, 8, 1) + 1e-12
        );
        // A crawling codec must lose to raw egress on a 100 GbE lane set.
        let slow = CodecProfile::from_entries(
            Codec::ALL
                .iter()
                .map(|c| {
                    (
                        *c,
                        CodecThroughput {
                            compress_bps: 1.0e6,
                            ratio: 4.0,
                        },
                    )
                })
                .collect(),
        );
        let p = planner(8).with_codec_profile(slow);
        assert_eq!(p.choose_codec_stream(8, 3), Codec::None);
        let plan = p
            .plan(
                EngineKind::Sst,
                &intent(
                    "adios2_compression = 'auto',\n \
                     adios2_sst_address = '127.0.0.1:1',",
                ),
            )
            .unwrap();
        assert_eq!(plan.codec.value, Codec::None);
        assert_eq!(plan.codec.source, DecisionSource::Auto);
    }

    #[test]
    fn relay_fanout_resolves_and_renders_conditionally() {
        let p = planner(8);
        // Knob unset: no relay decision, no relay rows — pre-relay plan
        // output stays byte-identical (the golden-compat contract).
        let addrs: Vec<String> = (0..9).map(|i| format!("127.0.0.1:{}", 5000 + i)).collect();
        let direct = p
            .plan(
                EngineKind::Sst,
                &intent(&format!("adios2_sst_address = '{}',", addrs.join(", "))),
            )
            .unwrap();
        assert!(direct.relay_fanout.is_none());
        assert_eq!(direct.relay_nodes(), 0);
        assert!(!direct.render("hist").contains("relay"));
        // 'auto' at 9 full consumers: ceil(sqrt(9)) = 3 leaves per relay,
        // 3 relay nodes, and the tree must score above direct.
        let tree = p
            .plan(
                EngineKind::Sst,
                &intent(&format!(
                    "adios2_sst_address = '{}',\n adios2_relay_fanout = 'auto',",
                    addrs.join(", ")
                )),
            )
            .unwrap();
        let rf = tree.relay_fanout.expect("auto knob must resolve");
        assert_eq!(rf.value, 3);
        assert_eq!(rf.source, DecisionSource::Auto);
        assert_eq!(tree.relay_nodes(), 3);
        assert!(
            tree.predicted.fanout_advantage > 1.0,
            "2-level tree over 9 full consumers must beat direct: {:.2}",
            tree.predicted.fanout_advantage
        );
        // The producer-egress relief shows up in the predicted write
        // time: 3 relay streams beat 9 direct consumer streams.
        assert!(tree.predicted.t_write < direct.predicted.t_write);
        let table = tree.render("hist");
        assert!(table.contains("relay_fanout"));
        assert!(table.contains("relay_nodes"));
        // A pinned 0 renders the row (the user asked for direct) but
        // derives no relay nodes and keeps the direct advantage score.
        let pinned = p
            .plan(
                EngineKind::Sst,
                &intent(&format!(
                    "adios2_sst_address = '{}',\n adios2_relay_fanout = 0,",
                    addrs.join(", ")
                )),
            )
            .unwrap();
        let rf = pinned.relay_fanout.expect("pinned knob must resolve");
        assert_eq!(rf.value, 0);
        assert_eq!(rf.source, DecisionSource::Namelist);
        assert_eq!(pinned.relay_nodes(), 0);
        assert!(pinned.render("hist").contains("relay_fanout"));
        assert!(
            (pinned.predicted.t_write - direct.predicted.t_write).abs() < 1e-12,
            "fanout 0 must predict exactly the direct plan"
        );
        // Below 8 consumers 'auto' stays direct.
        let few = p
            .plan(
                EngineKind::Sst,
                &intent(
                    "adios2_sst_address = '127.0.0.1:1, 127.0.0.1:2',\n \
                     adios2_relay_fanout = 'auto',",
                ),
            )
            .unwrap();
        assert_eq!(few.relay_fanout.unwrap().value, 0);
        // Stamped provenance carries the relay decision.
        let mut r = BenchReport::new("relay_plan");
        tree.stamp(&mut r);
        let j = r.to_json();
        assert!(j.contains("\"plan_relay_fanout\": 3"));
        assert!(j.contains("\"plan_relay_nodes\": 3"));
    }

    #[test]
    fn auto_target_honors_standalone_drain_flag() {
        let p = planner(8);
        let plan = p
            .plan(
                EngineKind::Bp4,
                &intent("adios2_target = 'auto',\n adios2_drain = .false.,"),
            )
            .unwrap();
        assert_eq!(
            plan.target.value,
            Target::BurstBuffer { drain: false },
            "explicit adios2_drain=.false. must survive an auto target"
        );
        assert!(!plan.bb_live());
    }

    #[test]
    fn autotuned_never_slower_than_any_fixed_aggregator_count() {
        // The fig10 acceptance property at the score level: the sweep's
        // argmin is ≤ every fixed candidate, at both paper node counts.
        for nodes in [1usize, 8] {
            let p = planner(nodes);
            for target in [Target::Pfs, Target::BurstBuffer { drain: true }] {
                let (_, best) = p.choose_aggregators(target, 1);
                for c in p.agg_candidates() {
                    let s = p.score_aggregators(c, p.shape.step_bytes, target, 1);
                    assert!(best <= s + 1e-12, "{nodes} nodes {target:?}: {best} > {s}");
                }
            }
        }
    }
}
