//! Closed-loop adaptive re-planning (DESIGN.md §17).
//!
//! The PR-5 planner resolves every `'auto'` knob once from static
//! [`CodecProfile`](super::CodecProfile) defaults and never looks back —
//! even though [`Bp4Engine`](crate::adios::engine::bp4::Bp4Engine) already
//! measures its per-step drain watermark and the SST lanes ledger real
//! per-consumer egress.  This module closes the loop: the engine's
//! measured [`EngineFeedback`] flows into a [`FeedbackController`], which
//! distills it to a [`MeasuredProfile`], checks the replan [`Trigger`]s,
//! and — past the hysteresis gates — re-resolves the intent's `'auto'`
//! knobs under the *measured* testbed between steps.
//!
//! Hysteresis is load-bearing: a replan only fires when (a) a trigger
//! metric is out of band, (b) the cooldown window since the last replan
//! has passed, and (c) the predicted relative gain — net of the replan's
//! own charge ([`CostModel::t_replan`](crate::sim::CostModel::t_replan))
//! — clears the improvement threshold.  A healthy run therefore replans
//! **zero** times and its plan provenance stays byte-identical to the
//! open-loop path.
//!
//! Every accepted change is recorded as a [`PlanChange`] (step, trigger
//! metric, old→new knob, predicted gain) and stamped into the
//! `BENCH_*.json` `plan_changes` array by [`stamp_changes`].

use crate::adios::engine::{EngineFeedback, KnobUpdate, Target};
use crate::adios::EngineKind;
use crate::metrics::BenchReport;
use crate::sim::MeasuredProfile;
use crate::Result;

use super::intent::{IoIntent, Knob, Setting};
use super::planner::{IoPlan, Planner};

/// Hysteresis constants of the replan loop (DESIGN.md §17).
#[derive(Debug, Clone, Copy)]
pub struct ReplanPolicy {
    /// Minimum steps between accepted replans (and the horizon the replan
    /// charge is amortized over).
    pub cooldown_steps: usize,
    /// Minimum predicted relative gain `(t_stay − t_cand − charge) /
    /// t_stay` an accepted replan must clear.
    pub min_gain: f64,
    /// Drain-watermark trigger: frames enqueued-but-not-durable at a step
    /// boundary before the drain counts as lagging the step cadence.
    pub backlog_frames: usize,
    /// Bandwidth-collapse trigger: measured PFS / drain bandwidth
    /// fraction below this is out of band.
    pub bw_collapse_frac: f64,
    /// Codec-lag trigger: measured compress throughput below this
    /// fraction of the profile's assumption is out of band.
    pub codec_lag_frac: f64,
}

impl Default for ReplanPolicy {
    fn default() -> Self {
        ReplanPolicy {
            cooldown_steps: 3,
            min_gain: 0.15,
            backlog_frames: 2,
            bw_collapse_frac: 0.6,
            codec_lag_frac: 0.5,
        }
    }
}

/// Which measured signal tripped a replan evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// The drain watermark lags the step cadence (backlog at a boundary).
    DrainLag,
    /// Measured compress throughput can't keep pace with the profile's
    /// assumption for the planned codec.
    CodecLag,
    /// Sustained drain/PFS bandwidth fell below the cost model's
    /// assumption.
    BandwidthCollapse,
}

impl Trigger {
    pub fn name(&self) -> &'static str {
        match self {
            Trigger::DrainLag => "drain_lag",
            Trigger::CodecLag => "codec_lag",
            Trigger::BandwidthCollapse => "bandwidth_collapse",
        }
    }
}

/// Provenance record of one accepted knob change (the `plan_changes`
/// array entry of `BENCH_*.json`).
#[derive(Debug, Clone)]
pub struct PlanChange {
    /// Step whose feedback drove the replan.
    pub step: usize,
    pub trigger: Trigger,
    /// The trigger metric, rendered (`"pfs_bw_frac=0.25"`).
    pub metric: String,
    /// Which knob moved: `"target"`, `"codec"`, `"aggregators_per_node"`.
    pub knob: &'static str,
    pub old: String,
    pub new: String,
    /// Predicted relative gain of the whole replan, net of its charge.
    pub predicted_gain: f64,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl PlanChange {
    /// One JSON object for the `plan_changes` provenance array.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"step\": {}, \"trigger\": \"{}\", \"metric\": \"{}\", \
             \"knob\": \"{}\", \"old\": \"{}\", \"new\": \"{}\", \
             \"predicted_gain\": {:.4}}}",
            self.step,
            self.trigger.name(),
            esc(&self.metric),
            esc(self.knob),
            esc(&self.old),
            esc(&self.new),
            self.predicted_gain,
        )
    }

    /// One report line for `stormio run` output.
    pub fn summary(&self) -> String {
        format!(
            "replan @step {}: {} {} -> {} [{} {}] predicted gain {:.0}%",
            self.step,
            self.knob,
            self.old,
            self.new,
            self.trigger.name(),
            self.metric,
            self.predicted_gain * 100.0,
        )
    }
}

/// Stamp the replan provenance into a bench report.  With no changes the
/// report's built-in `"plan_changes": []` default already says so — the
/// artifact stays byte-identical to an open-loop run's.
pub fn stamp_changes(r: &mut BenchReport, changes: &[PlanChange]) {
    if changes.is_empty() {
        return;
    }
    let body: Vec<String> = changes.iter().map(|c| c.to_json()).collect();
    r.raw("plan_changes", &format!("[{}]", body.join(", ")));
}

fn target_label(t: Target) -> &'static str {
    match t {
        Target::Pfs => "pfs",
        Target::BurstBuffer { drain: true } => "burstbuffer+drain",
        Target::BurstBuffer { drain: false } => "burstbuffer",
        Target::Object => "object",
    }
}

/// Re-pin an intent to the *current* plan's resolved knob values, so the
/// stay-put baseline can be scored under the measured testbed with the
/// same machinery as the candidate.
fn pin_intent(base: &IoIntent, plan: &IoPlan) -> IoIntent {
    let mut i = base.clone();
    i.aggregators = Knob::namelist(Setting::Explicit(plan.aggs_per_node.value));
    i.codec = Knob::namelist(Setting::Explicit(plan.codec.value));
    i.target = Knob::namelist(Setting::Explicit(plan.target.value));
    i
}

/// The closed-loop controller: owns the open-loop planner + intent + the
/// currently-live plan, digests per-step [`EngineFeedback`], and emits a
/// [`KnobUpdate`] whenever a replan clears every hysteresis gate.
#[derive(Debug, Clone)]
pub struct FeedbackController {
    policy: ReplanPolicy,
    planner: Planner,
    engine: EngineKind,
    intent: IoIntent,
    plan: IoPlan,
    last_replan: Option<usize>,
    changes: Vec<PlanChange>,
}

impl FeedbackController {
    /// Wrap an already-resolved plan (the launcher's normal path: the
    /// open-loop plan was built and reported before the run started).
    pub fn new(planner: Planner, intent: IoIntent, plan: IoPlan) -> FeedbackController {
        FeedbackController {
            policy: ReplanPolicy::default(),
            engine: plan.engine.clone(),
            planner,
            intent,
            plan,
            last_replan: None,
            changes: Vec::new(),
        }
    }

    pub fn with_policy(mut self, policy: ReplanPolicy) -> FeedbackController {
        self.policy = policy;
        self
    }

    /// The currently-live plan (the candidate after an accepted replan).
    pub fn plan(&self) -> &IoPlan {
        &self.plan
    }

    /// Every accepted change so far, in step order.
    pub fn changes(&self) -> &[PlanChange] {
        &self.changes
    }

    /// Distill one step's feedback into a [`MeasuredProfile`]: the drain
    /// fraction is the durable share of enqueued frames, the compress
    /// fraction the measured-vs-assumed throughput of the planned codec.
    fn measured_from(&self, fb: &EngineFeedback) -> MeasuredProfile {
        // A frame or two still in flight at the sampling instant is
        // normal pipelining, not a bandwidth signal — only a backlog at
        // the trigger threshold counts as a lagging drain.
        let drain_bw_frac = if fb.frames_enqueued == 0
            || fb.drain_backlog() < self.policy.backlog_frames.max(1)
        {
            1.0
        } else {
            fb.frames_durable as f64 / fb.frames_enqueued as f64
        };
        let compress_frac = match self.planner.codecs.compress_bps(self.plan.codec.value) {
            Some(assumed)
                if assumed > 0.0 && fb.compress_bps.is_finite() && fb.compress_bps > 0.0 =>
            {
                (fb.compress_bps / assumed).min(1.0)
            }
            _ => 1.0,
        };
        MeasuredProfile {
            drain_bw_frac,
            pfs_bw_frac: fb.pfs_bw_frac,
            compress_frac,
        }
        .clamped()
    }

    /// Which triggers are out of band for this sample (empty = healthy:
    /// the controller then does no planning work at all).
    fn triggers(&self, fb: &EngineFeedback, m: &MeasuredProfile) -> Vec<(Trigger, String)> {
        let mut out = Vec::new();
        if fb.drain_backlog() >= self.policy.backlog_frames.max(1) {
            out.push((
                Trigger::DrainLag,
                format!("drain_backlog={}", fb.drain_backlog()),
            ));
        }
        if let Some(assumed) = self.planner.codecs.compress_bps(self.plan.codec.value) {
            if fb.compress_bps.is_finite()
                && fb.compress_bps > 0.0
                && fb.compress_bps < self.policy.codec_lag_frac * assumed
            {
                out.push((
                    Trigger::CodecLag,
                    format!(
                        "compress_bps={:.2e} assumed={:.2e}",
                        fb.compress_bps, assumed
                    ),
                ));
            }
        }
        if m.pfs_bw_frac < self.policy.bw_collapse_frac {
            out.push((
                Trigger::BandwidthCollapse,
                format!("pfs_bw_frac={:.2}", m.pfs_bw_frac),
            ));
        } else if m.drain_bw_frac < self.policy.bw_collapse_frac {
            out.push((
                Trigger::BandwidthCollapse,
                format!("drain_bw_frac={:.2}", m.drain_bw_frac),
            ));
        }
        out
    }

    /// Digest one step's feedback.  Returns the knob delta to broadcast
    /// and apply when a replan cleared every gate, `None` otherwise (the
    /// overwhelmingly common case).
    pub fn observe(&mut self, fb: &EngineFeedback) -> Result<Option<KnobUpdate>> {
        // Live egress fractions feed the fan-out scoring even when no
        // replan fires — the next evaluation sees the cropped
        // subscriptions actually in force.
        if fb.stored_bytes > 0 && !fb.egress_per_consumer.is_empty() {
            let stored = fb.stored_bytes as f64;
            self.planner.consumer_fracs = fb
                .egress_per_consumer
                .iter()
                .map(|&b| b as f64 / stored)
                .collect();
        }

        let m = self.measured_from(fb);
        let triggers = self.triggers(fb, &m);
        if triggers.is_empty() {
            return Ok(None);
        }
        // Cooldown: one replan per window, and the window also amortizes
        // the replan charge in the gain test below.
        if let Some(last) = self.last_replan {
            if fb.step < last + self.policy.cooldown_steps.max(1) {
                return Ok(None);
            }
        }

        let mp = self.planner.with_measured(&m);
        let stay = mp.plan(self.engine.clone(), &pin_intent(&self.intent, &self.plan))?;
        let cand = mp.plan(self.engine.clone(), &self.intent)?;

        let mut diffs: Vec<(&'static str, String, String)> = Vec::new();
        if cand.aggs_per_node.value != self.plan.aggs_per_node.value {
            diffs.push((
                "aggregators_per_node",
                self.plan.aggs_per_node.value.to_string(),
                cand.aggs_per_node.value.to_string(),
            ));
        }
        if cand.codec.value != self.plan.codec.value {
            diffs.push((
                "codec",
                self.plan.codec.value.name().to_string(),
                cand.codec.value.name().to_string(),
            ));
        }
        if cand.target.value != self.plan.target.value {
            diffs.push((
                "target",
                target_label(self.plan.target.value).to_string(),
                target_label(cand.target.value).to_string(),
            ));
        }
        if diffs.is_empty() {
            return Ok(None);
        }

        // Predicted gain, net of the replan's own charge amortized over
        // the cooldown window.
        let layout_change = cand.aggs_per_node.value != self.plan.aggs_per_node.value
            || cand.target.value != self.plan.target.value;
        let naggs = cand.aggs_per_node.value * self.planner.cost.hw.nodes.max(1);
        let charge = self.planner.cost.t_replan(layout_change, naggs)
            / self.policy.cooldown_steps.max(1) as f64;
        let t_stay = stay.predicted.t_durable;
        let t_cand = cand.predicted.t_durable;
        let gain = (t_stay - t_cand - charge) / t_stay.max(1e-12);
        if !(gain >= self.policy.min_gain) {
            return Ok(None);
        }

        let (trigger, metric) = triggers[0].clone();
        let mut update = KnobUpdate::default();
        for (knob, old, new) in diffs {
            match knob {
                "aggregators_per_node" => update.aggs_per_node = Some(cand.aggs_per_node.value),
                "codec" => update.operator = Some(cand.operator),
                "target" => update.target = Some(cand.target.value),
                _ => unreachable!(),
            }
            self.changes.push(PlanChange {
                step: fb.step,
                trigger,
                metric: metric.clone(),
                knob,
                old,
                new,
                predicted_gain: gain,
            });
        }
        // Codec moves ride along on the operator template even when the
        // codec itself is the only delta; a target/aggs move also wants
        // the candidate's (possibly re-chosen) operator.
        if update.operator.is_none() && cand.operator != self.plan.operator {
            update.operator = Some(cand.operator);
        }
        self.plan = cand;
        self.last_replan = Some(fb.step);
        Ok(Some(update))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namelist::Namelist;
    use crate::plan::WorkloadShape;
    use crate::sim::{CostModel, HardwareSpec};

    fn planner() -> Planner {
        Planner::new(
            CostModel::new(HardwareSpec::paper_testbed(2)),
            WorkloadShape::paper(),
        )
    }

    fn intent(body: &str) -> IoIntent {
        let nl = Namelist::parse(&format!("&time_control\n{body}\n/\n")).unwrap();
        IoIntent::from_time_control(nl.group("time_control").unwrap()).unwrap()
    }

    fn auto_intent() -> IoIntent {
        intent(
            "adios2_num_aggregators = 'auto',\n adios2_compression = 'auto',\n \
             adios2_target = 'auto',",
        )
    }

    fn controller() -> (FeedbackController, IoPlan) {
        let p = planner();
        let i = auto_intent();
        let open_loop = p.plan(EngineKind::Bp4, &i).unwrap();
        (
            FeedbackController::new(p, i, open_loop.clone()),
            open_loop,
        )
    }

    fn healthy(step: usize) -> EngineFeedback {
        EngineFeedback {
            step,
            stored_bytes: 1 << 30,
            frames_enqueued: step + 1,
            frames_durable: step + 1,
            ..EngineFeedback::default()
        }
    }

    fn collapsed(step: usize) -> EngineFeedback {
        EngineFeedback {
            step,
            stored_bytes: 1 << 30,
            frames_enqueued: step + 1,
            frames_durable: step.saturating_sub(2),
            pfs_bw_frac: 0.25,
            ..EngineFeedback::default()
        }
    }

    #[test]
    fn healthy_run_replans_zero_times_and_stamp_is_byte_identical() {
        let (mut ctl, open_loop) = controller();
        for step in 0..8 {
            assert_eq!(ctl.observe(&healthy(step)).unwrap(), None);
        }
        assert!(ctl.changes().is_empty());
        // The live plan is still the open-loop plan, decision table and
        // all.
        assert_eq!(ctl.plan().render("hist"), open_loop.render("hist"));
        // And the BENCH provenance is byte-identical to an open-loop
        // stamp: zero churn leaves no trace.
        let mut adaptive = BenchReport::new("x");
        ctl.plan().stamp(&mut adaptive);
        stamp_changes(&mut adaptive, ctl.changes());
        let mut open = BenchReport::new("x");
        open_loop.stamp(&mut open);
        assert_eq!(adaptive.to_json(), open.to_json());
    }

    #[test]
    fn bandwidth_collapse_retargets_to_the_object_space() {
        let (mut ctl, open_loop) = controller();
        // Healthy lone-run CONUS plan lands on the drained burst buffer.
        assert_eq!(
            open_loop.target.value,
            Target::BurstBuffer { drain: true }
        );
        let update = ctl.observe(&collapsed(4)).unwrap().expect("should replan");
        assert_eq!(update.target, Some(Target::Object));
        assert_eq!(ctl.plan().target.value, Target::Object);
        let change = ctl
            .changes()
            .iter()
            .find(|c| c.knob == "target")
            .expect("target change recorded");
        assert_eq!(change.step, 4);
        assert_eq!(change.old, "burstbuffer+drain");
        assert_eq!(change.new, "object");
        assert!(change.predicted_gain > 0.0);
        // Provenance renders as a JSON object naming the trigger.
        let j = change.to_json();
        assert!(j.contains("\"knob\": \"target\""));
        assert!(j.contains("\"trigger\": \""));
        // The stamped report carries the non-empty array exactly once.
        let mut r = BenchReport::new("x");
        ctl.plan().stamp(&mut r);
        stamp_changes(&mut r, ctl.changes());
        let json = r.to_json();
        assert!(json.contains("\"plan_changes\": [{"));
        assert_eq!(json.matches("plan_changes").count(), 1);
    }

    #[test]
    fn cooldown_window_suppresses_consecutive_replans() {
        let (mut ctl, _) = controller();
        ctl.last_replan = Some(3);
        // cooldown_steps = 3: steps 4 and 5 are inside the window even
        // though the collapse trigger fires on every sample.
        assert_eq!(ctl.observe(&collapsed(4)).unwrap(), None);
        assert_eq!(ctl.observe(&collapsed(5)).unwrap(), None);
        assert!(ctl.changes().is_empty());
        // The window closes at last + cooldown.
        assert!(ctl.observe(&collapsed(6)).unwrap().is_some());
        assert!(!ctl.changes().is_empty());
    }

    #[test]
    fn gain_under_threshold_vetoes_the_replan() {
        let (ctl, _) = controller();
        // gain = (t_stay − t_cand − charge)/t_stay is strictly below 1.
        let mut ctl = ctl.with_policy(ReplanPolicy {
            min_gain: 1.0,
            ..ReplanPolicy::default()
        });
        assert_eq!(ctl.observe(&collapsed(4)).unwrap(), None);
        assert!(ctl.changes().is_empty());
    }

    #[test]
    fn recovered_conditions_stop_triggering_after_a_replan() {
        let (mut ctl, _) = controller();
        assert!(ctl.observe(&collapsed(4)).unwrap().is_some());
        // Post-replan, healthy samples never re-enter the planner: the
        // change log stays put.
        let n = ctl.changes().len();
        for step in 7..12 {
            assert_eq!(ctl.observe(&healthy(step)).unwrap(), None);
        }
        assert_eq!(ctl.changes().len(), n);
    }

    #[test]
    fn egress_ledger_updates_consumer_fractions() {
        let p = planner();
        let i = intent("adios2_sst_address = 'c1:1, c2:2',");
        let plan = p.plan(EngineKind::Sst, &i).unwrap();
        let mut ctl = FeedbackController::new(p, i, plan);
        let fb = EngineFeedback {
            step: 0,
            stored_bytes: 1000,
            egress_per_consumer: vec![250, 1000],
            ..EngineFeedback::default()
        };
        assert_eq!(ctl.observe(&fb).unwrap(), None);
        assert_eq!(ctl.planner.consumer_fracs, vec![0.25, 1.0]);
    }

    #[test]
    fn fanout_advantage_is_plan_aware_under_cropped_subscriptions() {
        // Two lanes per node keep the chain constant small relative to
        // the relay's full-step rank-0 gather, so the advantage's
        // direction under cropping is governed by the gather term.
        let addrs = "adios2_num_aggregators = 2,\n \
                     adios2_sst_address = 'c1:1, c2:2, c3:3, c4:4',";
        let p = planner();
        let full = p.plan(EngineKind::Sst, &intent(addrs)).unwrap();
        let boxed = p
            .clone()
            .with_consumer_fractions(vec![0.2; 4])
            .plan(EngineKind::Sst, &intent(addrs))
            .unwrap();
        // Cropped subscriptions shrink per-consumer egress 5× …
        for (b, f) in boxed.consumers.iter().zip(&full.consumers) {
            assert!((b.est_bytes - 0.2 * f.est_bytes).abs() < 1e-6 * f.est_bytes);
        }
        // … which cheapens the fan-out relative to the rank-0 relay (the
        // relay still funnels the full step through one root), so the
        // plan-aware advantage must rise and the predicted step cost
        // fall.
        assert!(
            boxed.predicted.fanout_advantage > full.predicted.fanout_advantage,
            "boxed {} vs full {}",
            boxed.predicted.fanout_advantage,
            full.predicted.fanout_advantage
        );
        assert!(boxed.predicted.t_write < full.predicted.t_write);
    }
}
