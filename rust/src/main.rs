//! `stormio` — leader binary: run forecasts, convert output, inspect
//! artifacts.  (clap is not in the offline vendor set; argument parsing is
//! by hand.)

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use stormio::{convert, launcher, runtime};

const USAGE: &str = "\
stormio — WRF + ADIOS2 reproduction (Laufer & Fredj 2022)

USAGE:
  stormio run <namelist.input> [--artifacts DIR]
      Run a forecast configured by a WRF-style namelist.

  stormio plan <namelist.input> [--measure] [--measure-out FILE]
                [--measure-in FILE]
      Dry-run the I/O planner: resolve every adios2_* knob (including
      'auto' sentinels, decided from the cost model) and print the
      decision table with provenance plus the predicted virtual costs
      (t_write, time_to_first_analysis) — without running the model.
      The target sweep is three-way (pfs | bb | object); with
      adios2_ensemble_writers > 1 it scores time-to-durable under
      cross-run PFS contention.  With --measure, codec knobs are
      resolved from per-codec throughput/ratio microbenchmarked on
      this host instead of the paper-testbed defaults.
      --measure-out FILE caches the measured profile as JSON (implies
      --measure); --measure-in FILE reuses a cached profile instead
      of re-measuring.

  stormio convert <dir.bp> <out_dir> [--no-compress]
      Convert every step of a BP directory to NetCDF-style files
      (the paper's §IV backwards-compatibility converter).

  stormio follow <dir.bp> <out_dir> [--bb BB_ROOT] [--timeout SECS]
                 [--no-compress]
      Tail a *live* BP directory (a producer running with
      LivePublish) and convert each step to NetCDF as it is
      published; exits when the producer completes.  With --bb, tail
      a draining burst-buffer run through both tiers: each step is
      read from the node-local replica until the drain watermark
      says its PFS copy is complete (\"follow the drain\").
      Streams written with adios2_target = 'object' are followed
      transparently: blocks come from the run's object space.

  stormio insitu <namelist.input> [--artifacts DIR]
      Run a forecast streaming over the SST fan-out data plane to
      three concurrent consumers: in-situ analysis (subscribed to
      its variable only — selection pushdown), live NetCDF
      conversion, and a raw step archiver (paper §V-F, Fig 8).
      The producer runs the wire v4 service broker, and a fourth
      consumer attaches mid-stream through it (late join + replay).

  stormio attach <addr | dir | contact_file> [--sub SPEC]
                 [--timeout SECS]
      Join a *running* broker-enabled SST producer mid-stream
      (wire v4): admitted at the next step boundary, first step
      replayed from the producer's crop cache, then tail steps
      until end-of-stream.  <addr> is the broker host:port, or a
      path to the producer's output directory / sst_broker.contact
      file.  --sub crops the subscription: ';'-separated entries,
      each NAME or NAME[start:count,...] per dimension
      (e.g. --sub 'T[1:2,0:6];PSFC').

  stormio relay <addr | dir | contact_file> [--listen ADDR]
                [--depth-hint N] [--timeout SECS]
      Run a relay node of the SST distribution tree (DESIGN.md
      §16): subscribe to a running broker-enabled producer (or an
      upper relay) as an ordinary wire v4 consumer and re-serve the
      stream downstream as a single-lane producer with its own
      broker, so leaves (or deeper relays) attach *through* this
      node with `stormio attach <relay contact>`.  Producer egress
      stays flat as leaves join; each level's bounded queues confine
      a slow leaf's back-pressure to its own subtree.  --listen
      binds the relay's broker (default 127.0.0.1:0); --depth-hint
      labels the ledger with the relay's tree level.  Exits when the
      upstream stream ends, after closing every downstream lane.

  stormio stitch <out.nc> <part.nc> [part.nc ...]
      Stitch split-NetCDF (io_form=102) per-rank files into one file.

  stormio info [--artifacts DIR]
      Show the AOT artifact manifest and PJRT platform.

  stormio version
";

fn artifacts_flag(args: &[String]) -> PathBuf {
    args.windows(2)
        .find(|w| w[0] == "--artifacts")
        .map(|w| PathBuf::from(&w[1]))
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn real_main() -> stormio::Result<i32> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => {
            let nl = args.get(1).ok_or_else(|| {
                stormio::Error::config("run: missing namelist path".to_string())
            })?;
            launcher::run_from_namelist(Path::new(nl), &artifacts_flag(&args))?;
            Ok(0)
        }
        Some("plan") => {
            let nl = args.get(1).ok_or_else(|| {
                stormio::Error::config("plan: missing namelist path".to_string())
            })?;
            let measure = args.iter().any(|a| a == "--measure");
            let measure_out = args
                .windows(2)
                .find(|w| w[0] == "--measure-out")
                .map(|w| PathBuf::from(&w[1]));
            let measure_in = args
                .windows(2)
                .find(|w| w[0] == "--measure-in")
                .map(|w| PathBuf::from(&w[1]));
            launcher::plan_from_namelist(
                Path::new(nl),
                measure,
                measure_out.as_deref(),
                measure_in.as_deref(),
            )?;
            Ok(0)
        }
        Some("insitu") => {
            let nl = args.get(1).ok_or_else(|| {
                stormio::Error::config("insitu: missing namelist path".to_string())
            })?;
            launcher::run_insitu_from_namelist(Path::new(nl), &artifacts_flag(&args))?;
            Ok(0)
        }
        Some("attach") => {
            let target = args.get(1).ok_or_else(|| {
                stormio::Error::config(
                    "attach: missing broker address or producer directory".to_string(),
                )
            })?;
            let sub = args
                .windows(2)
                .find(|w| w[0] == "--sub")
                .map(|w| w[1].as_str());
            let secs: u64 = args
                .windows(2)
                .find(|w| w[0] == "--timeout")
                .and_then(|w| w[1].parse().ok())
                .unwrap_or(300);
            launcher::run_attach(target, sub, secs)?;
            Ok(0)
        }
        Some("relay") => {
            let target = args.get(1).ok_or_else(|| {
                stormio::Error::config(
                    "relay: missing upstream broker address or producer directory"
                        .to_string(),
                )
            })?;
            let listen = args
                .windows(2)
                .find(|w| w[0] == "--listen")
                .map(|w| w[1].as_str())
                .unwrap_or("127.0.0.1:0");
            let depth: u32 = args
                .windows(2)
                .find(|w| w[0] == "--depth-hint")
                .and_then(|w| w[1].parse().ok())
                .unwrap_or(1);
            let secs: u64 = args
                .windows(2)
                .find(|w| w[0] == "--timeout")
                .and_then(|w| w[1].parse().ok())
                .unwrap_or(300);
            launcher::run_relay(target, listen, depth, secs)?;
            Ok(0)
        }
        Some("convert") => {
            let bp = args.get(1).map(PathBuf::from);
            let out = args.get(2).map(PathBuf::from);
            let (Some(bp), Some(out)) = (bp, out) else {
                eprintln!("{USAGE}");
                return Ok(2);
            };
            let compress = !args.iter().any(|a| a == "--no-compress");
            let sw = stormio::metrics::Stopwatch::start();
            let paths = convert::bp_to_nc_all(&bp, &out, compress)?;
            println!(
                "converted {} step(s) from {} in {:.2}s:",
                paths.len(),
                bp.display(),
                sw.secs()
            );
            for p in paths {
                println!("  {}", p.display());
            }
            Ok(0)
        }
        Some("follow") => {
            let bp = args.get(1).map(PathBuf::from);
            let out = args.get(2).map(PathBuf::from);
            let (Some(bp), Some(out)) = (bp, out) else {
                eprintln!("{USAGE}");
                return Ok(2);
            };
            let secs: u64 = args
                .windows(2)
                .find(|w| w[0] == "--timeout")
                .and_then(|w| w[1].parse().ok())
                .unwrap_or(300);
            let compress = !args.iter().any(|a| a == "--no-compress");
            let stem = bp
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_else(|| "out".into());
            let bb_root = args
                .windows(2)
                .find(|w| w[0] == "--bb")
                .map(|w| PathBuf::from(&w[1]));
            let poll = std::time::Duration::from_millis(50);
            let timeout = std::time::Duration::from_secs(secs);
            let sw = stormio::metrics::Stopwatch::start();
            if let Some(bb_root) = bb_root {
                // Tiered follow: serve each step from the fastest tier
                // that holds it (burst buffer until drained, then PFS).
                let mut src =
                    stormio::adios::bp::follower::TieredFollower::open(&bp, &bb_root, poll)?;
                let paths = convert::stream_to_nc(&mut src, &out, &stem, compress, timeout)?;
                let (bb, fin) = src.tier_counts();
                let fin_label = match src.final_tier_name() {
                    "object" => "object space",
                    _ => "PFS",
                };
                println!(
                    "followed {} live across tiers: converted {} step(s) in {:.2}s \
                     ({bb} served from the burst buffer, {fin} from the {fin_label})",
                    bp.display(),
                    paths.len(),
                    sw.secs()
                );
            } else {
                let mut src = stormio::adios::bp::follower::BpFollower::open(&bp, poll)?;
                let paths = convert::stream_to_nc(&mut src, &out, &stem, compress, timeout)?;
                println!(
                    "followed {} live: converted {} step(s) in {:.2}s",
                    bp.display(),
                    paths.len(),
                    sw.secs()
                );
            }
            Ok(0)
        }
        Some("stitch") => {
            let out = args.get(1).map(PathBuf::from);
            let parts: Vec<PathBuf> = args[2..].iter().map(PathBuf::from).collect();
            let Some(out) = out else {
                eprintln!("{USAGE}");
                return Ok(2);
            };
            let n = convert::stitch_split(&parts, &out, false)?;
            println!("stitched {} parts into {} ({} bytes)", parts.len(), out.display(), n);
            Ok(0)
        }
        Some("info") => {
            let dir = artifacts_flag(&args);
            let man = runtime::Manifest::load(&dir)?;
            let rt = runtime::XlaRuntime::new()?;
            println!("pjrt platform: {}", rt.platform());
            println!("artifacts dir: {}", man.dir.display());
            println!("halo {}  nf {}  fields {:?}", man.halo, man.nf, man.fields);
            for m in &man.models {
                println!("  model {}: nz={} patch {}x{} ({})", m.tag, m.nz, m.nyp, m.nxp, m.file);
            }
            for a in &man.analyses {
                println!("  analysis: nz={} grid {}x{} ({})", a.nz, a.ny, a.nx, a.file);
            }
            Ok(0)
        }
        Some("version") => {
            println!("stormio {}", stormio::version());
            Ok(0)
        }
        _ => {
            eprintln!("{USAGE}");
            Ok(2)
        }
    }
}

fn main() -> ExitCode {
    match real_main() {
        Ok(code) => ExitCode::from(code as u8),
        Err(e) => {
            eprintln!("stormio error: {e}");
            ExitCode::from(1)
        }
    }
}
