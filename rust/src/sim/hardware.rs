//! Hardware description of the simulated testbed.

/// All tunable constants of the virtual testbed, in SI units (bytes/s,
/// seconds).  Defaults mirror the paper's cluster (section V).
#[derive(Debug, Clone)]
pub struct HardwareSpec {
    /// Compute nodes brought online (paper: 1, 2, 4, 8).
    pub nodes: usize,
    /// MPI ranks per node (paper: 36 = 2 × 18 cores, dmpar).
    pub ranks_per_node: usize,

    // ---- interconnect -----------------------------------------------------
    /// Per-node NIC bandwidth, one 100 GbE port (ConnectX-6).
    pub link_bw: f64,
    /// Per-message interconnect latency.
    pub link_lat_s: f64,
    /// Intra-node (shared-memory) transfer bandwidth per rank pair.
    pub shm_bw: f64,
    /// Memory-copy bandwidth (buffering a put into the engine).
    pub mem_bw: f64,

    // ---- parallel file system (BeeGFS over 8 disks) ----------------------
    /// Aggregate PFS backend bandwidth (8 spinning disks × ~125 MB/s).
    pub pfs_agg_bw: f64,
    /// Per-client-stream ceiling (BeeGFS single-stream pipeline).
    pub pfs_stream_bw: f64,
    /// Number of backend storage targets (stripes/disks).
    pub pfs_targets: usize,
    /// Concurrent streams beyond which seek thrash sets in (≈ 4× targets).
    pub pfs_thrash_knee: usize,
    /// Thrash slope: efficiency = 1/(1 + slope · excess/targets).
    pub pfs_thrash_slope: f64,
    /// Storage-node ingress NIC (ConnectX-5, 100 Gb).
    pub pfs_ingress_bw: f64,

    // ---- metadata server ---------------------------------------------------
    /// Serialized cost of one file create at the MDS.
    pub mds_create_s: f64,
    /// Directory-lock contention: creates cost `n·create·(1 + n/knee)`.
    pub mds_storm_knee: f64,

    // ---- MPI-I/O (PnetCDF path) -------------------------------------------
    /// Per-variable collective synchronization constant (·log2(ranks)).
    pub coll_sync_s: f64,
    /// Byte-range lock serialization between collective writers:
    /// efficiency = 1/(1 + lock_c · (writers − 1)).
    pub lock_c: f64,
    /// Read-modify-write inflation for unaligned stripe writes.
    pub rmw_inflation: f64,

    // ---- node-local burst buffer (Intel DC P4510) --------------------------
    /// Sequential write bandwidth per node-local NVMe.
    pub nvme_write_bw: f64,
    /// Sequential read bandwidth (drain path).
    pub nvme_read_bw: f64,

    // ---- shared object store (DAOS-class landing tier) ---------------------
    /// Per-writer put bandwidth ceiling into the object space (one
    /// client's RPC/RDMA pipeline).
    pub obj_put_bw: f64,
    /// Aggregate object-space ingest across all concurrent writers —
    /// NVMe-backed key-value servers, far above the spinning-disk PFS.
    pub obj_agg_bw: f64,
    /// Per-object metadata/key-insert cost (no directory-lock convoy:
    /// flat per-key charge instead of the MDS storm formula).
    pub obj_md_s: f64,
    /// Cross-run PFS contention coefficient for N concurrent *runs*
    /// (ensemble members) sharing one file system: effective slowdown
    /// `1 + c·(runs − 1)` — seek interleaving between unrelated file
    /// trees, on top of the per-run stream model.
    pub pfs_cross_run_c: f64,

    // ---- workload scaling ---------------------------------------------------
    /// Multiplier mapping physically-moved bytes to CONUS-2.5km-scale bytes
    /// for *virtual time accounting only* (DESIGN.md §Substitutions: the
    /// single-core container cannot move ~8 GB × 5 reps × 20 configs).
    pub volume_scale: f64,
}

impl HardwareSpec {
    /// The paper's testbed (section V) with `nodes` compute nodes online.
    pub fn paper_testbed(nodes: usize) -> Self {
        HardwareSpec {
            nodes,
            ranks_per_node: 36,
            link_bw: 12.5e9,  // 100 GbE
            link_lat_s: 2e-6, // RoCE-class
            shm_bw: 6.0e9,
            mem_bw: 40.0e9,
            pfs_agg_bw: 1.0e9,     // 8 disks × 125 MB/s
            pfs_stream_bw: 0.35e9, // single BeeGFS client stream pipeline
            pfs_targets: 8,
            pfs_thrash_knee: 32,
            pfs_thrash_slope: 0.08,
            pfs_ingress_bw: 12.5e9, // ConnectX-5
            mds_create_s: 3e-3,
            mds_storm_knee: 256.0,
            coll_sync_s: 5e-3,
            lock_c: 1.0,
            rmw_inflation: 1.15,
            nvme_write_bw: 1.1e9,  // Intel DC P4510 datasheet
            nvme_read_bw: 2.85e9,
            obj_put_bw: 1.8e9,  // one client's RPC/RDMA pipeline
            obj_agg_bw: 24.0e9, // NVMe-backed KV servers, 2 × 100 GbE ingress
            obj_md_s: 2e-5,     // flat per-key insert, no create storm
            pfs_cross_run_c: 0.7,
            volume_scale: 1.0,
        }
    }

    /// Total MPI ranks.
    pub fn ranks(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Scale physical bytes to CONUS-scale bytes for virtual accounting.
    pub fn scaled(&self, bytes: u64) -> f64 {
        bytes as f64 * self.volume_scale
    }
}

impl Default for HardwareSpec {
    fn default() -> Self {
        HardwareSpec::paper_testbed(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let hw = HardwareSpec::paper_testbed(8);
        assert_eq!(hw.ranks(), 288);
        assert_eq!(hw.pfs_targets, 8);
        assert!(hw.nvme_write_bw < hw.nvme_read_bw);
    }

    #[test]
    fn volume_scaling() {
        let mut hw = HardwareSpec::paper_testbed(1);
        hw.volume_scale = 16.0;
        assert_eq!(hw.scaled(100), 1600.0);
    }
}
