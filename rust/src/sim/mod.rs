//! Virtual-time testbed substrate.
//!
//! The paper's evaluation ran on hardware we do not have: 8 compute nodes
//! (2×18-core Xeon Gold 6240, 384 GB), 100 GbE ConnectX-6 NICs, a BeeGFS
//! parallel file system striped over eight disks behind a ConnectX-5, and
//! an Intel DC P4510 NVMe burst buffer per node.  Per DESIGN.md
//! §Substitutions we rebuild that testbed as an *analytic contention
//! model*: every I/O backend moves **real bytes** through the real Rust
//! I/O stack (so formats, compression ratios and code paths are genuine)
//! and simultaneously charges its communication/storage phases against
//! [`hardware::HardwareSpec`] constants to produce **virtual** CONUS-scale
//! times.
//!
//! Calibration constants come from the testbed's datasheets (link rates,
//! disk counts, NVMe write bandwidth) and from standard middleware cost
//! parameters (MDS create latency, lock round-trips, collective sync) —
//! *not* from the paper's result tables, so the reproduced figures are
//! emergent (see EXPERIMENTS.md for paper-vs-measured).

pub mod cost;
pub mod hardware;
pub mod timeline;

pub use cost::{CostModel, MeasuredProfile, Phase, WriteCost};
pub use hardware::HardwareSpec;
pub use timeline::{SpanKind, Timeline};
