//! Gantt timeline for the Fig 8 end-to-end pipeline comparison.
//!
//! Records labelled spans per lane (e.g. `WRF+PnetCDF`, `WRF+ADIOS2-SST`,
//! `consumer`) and renders the run-time progression chart the paper shows:
//! compute blocks interleaved with I/O stalls for the legacy pipeline vs.
//! an almost-unbroken compute bar plus a concurrent consumer lane for the
//! in-situ pipeline.

/// What a span represents (affects rendering glyph).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Init,
    Compute,
    Io,
    PostProcess,
    Analysis,
    Idle,
}

impl SpanKind {
    fn glyph(self) -> char {
        match self {
            SpanKind::Init => 'i',
            SpanKind::Compute => '#',
            SpanKind::Io => 'W',
            SpanKind::PostProcess => 'P',
            SpanKind::Analysis => 'A',
            SpanKind::Idle => '.',
        }
    }
}

/// One labelled span on a lane.
#[derive(Debug, Clone)]
pub struct Span {
    pub lane: usize,
    pub label: String,
    pub kind: SpanKind,
    pub t0: f64,
    pub t1: f64,
}

/// A multi-lane timeline.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub lanes: Vec<String>,
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn lane(&mut self, name: impl Into<String>) -> usize {
        self.lanes.push(name.into());
        self.lanes.len() - 1
    }

    pub fn push(&mut self, lane: usize, kind: SpanKind, label: impl Into<String>, t0: f64, t1: f64) {
        assert!(t1 >= t0, "span ends before it starts");
        assert!(lane < self.lanes.len(), "unknown lane");
        self.spans.push(Span {
            lane,
            label: label.into(),
            kind,
            t0,
            t1,
        });
    }

    /// Append a span after the last span on `lane`; returns its end time.
    pub fn append(&mut self, lane: usize, kind: SpanKind, label: impl Into<String>, dur: f64) -> f64 {
        let t0 = self.lane_end(lane);
        self.push(lane, kind, label, t0, t0 + dur);
        t0 + dur
    }

    /// End time of the last span on a lane (0 if empty).
    pub fn lane_end(&self, lane: usize) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.lane == lane)
            .map(|s| s.t1)
            .fold(0.0, f64::max)
    }

    /// Overall makespan.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.t1).fold(0.0, f64::max)
    }

    /// Total time spent in a kind on one lane.
    pub fn total(&self, lane: usize, kind: SpanKind) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.lane == lane && s.kind == kind)
            .map(|s| s.t1 - s.t0)
            .sum()
    }

    /// ASCII Gantt rendering, `width` columns for the full makespan.
    pub fn render_ascii(&self, width: usize) -> String {
        let span = self.makespan().max(1e-9);
        let scale = width as f64 / span;
        let mut out = String::new();
        let name_w = self.lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        for (i, lane) in self.lanes.iter().enumerate() {
            let mut row = vec!['.'; width];
            for s in self.spans.iter().filter(|s| s.lane == i) {
                let a = (s.t0 * scale) as usize;
                let b = ((s.t1 * scale) as usize).min(width).max(a + 1);
                for c in row.iter_mut().take(b.min(width)).skip(a) {
                    *c = s.kind.glyph();
                }
            }
            out.push_str(&format!("{lane:>name_w$} |"));
            out.extend(row);
            out.push_str("|\n");
        }
        out.push_str(&format!(
            "{:>name_w$}  0{:·>width$}\n",
            "t",
            format!("{:.0}s", span),
        ));
        out.push_str("legend: i=init  #=compute  W=write/io  P=post-process  A=analysis\n");
        out
    }

    /// CSV dump (lane,label,kind,t0,t1) for external plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("lane,label,kind,t0,t1\n");
        for sp in &self.spans {
            s.push_str(&format!(
                "{},{},{:?},{:.4},{:.4}\n",
                self.lanes[sp.lane], sp.label, sp.kind, sp.t0, sp.t1
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_chains_spans() {
        let mut tl = Timeline::default();
        let l = tl.lane("wrf");
        let e1 = tl.append(l, SpanKind::Compute, "step", 10.0);
        let e2 = tl.append(l, SpanKind::Io, "hist", 5.0);
        assert_eq!(e1, 10.0);
        assert_eq!(e2, 15.0);
        assert_eq!(tl.makespan(), 15.0);
        assert_eq!(tl.total(l, SpanKind::Io), 5.0);
    }

    #[test]
    fn lanes_independent() {
        let mut tl = Timeline::default();
        let a = tl.lane("a");
        let b = tl.lane("b");
        tl.append(a, SpanKind::Compute, "c", 3.0);
        tl.append(b, SpanKind::Analysis, "an", 1.0);
        assert_eq!(tl.lane_end(a), 3.0);
        assert_eq!(tl.lane_end(b), 1.0);
    }

    #[test]
    fn render_contains_lane_names_and_glyphs() {
        let mut tl = Timeline::default();
        let l = tl.lane("wrf");
        tl.append(l, SpanKind::Compute, "c", 2.0);
        tl.append(l, SpanKind::Io, "w", 2.0);
        let art = tl.render_ascii(40);
        assert!(art.contains("wrf"));
        assert!(art.contains('#'));
        assert!(art.contains('W'));
    }

    #[test]
    #[should_panic(expected = "unknown lane")]
    fn unknown_lane_panics() {
        let mut tl = Timeline::default();
        tl.push(3, SpanKind::Io, "x", 0.0, 1.0);
    }

    #[test]
    fn csv_roundtrip_fields() {
        let mut tl = Timeline::default();
        let l = tl.lane("x");
        tl.append(l, SpanKind::Init, "init", 1.5);
        let csv = tl.to_csv();
        assert!(csv.contains("x,init,Init,0.0000,1.5000"));
    }
}
