//! Analytic cost model: communication + storage phases on the virtual
//! testbed.
//!
//! Each I/O backend composes the primitives here into a [`WriteCost`]
//! describing one history-file write at CONUS scale.  The primitives are
//! first-principles bandwidth/latency/contention formulas:
//!
//! * **fair-share streams** — a storage backend with `T` targets serving
//!   `s` concurrent streams delivers its aggregate bandwidth until seek
//!   thrash sets in past a knee (spinning disks), then efficiency decays
//!   as `1/(1 + slope·excess/targets)`;
//! * **byte-range locks** — N-1 collective writers serialize on file
//!   locks: `1/(1 + c·(writers−1))` (the classic MPI-I/O shared-file
//!   penalty PnetCDF pays and sub-file formats avoid);
//! * **MDS storms** — `n` near-simultaneous creates cost
//!   `n·t_create·(1 + n/knee)` (directory-lock convoy);
//! * **LogP-style collectives** — per-variable `α·log2(ranks)` sync for
//!   two-phase collective writes; all-to-all exchange bounded by the
//!   per-node link with `(n−1)/n` remote fraction.

use super::hardware::HardwareSpec;

/// One named phase of a write (for report tables and the Fig 8 Gantt).
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: &'static str,
    pub secs: f64,
    /// True if this phase blocks the application (perceived time);
    /// false if it proceeds in the background (e.g. BB drain).
    pub blocking: bool,
}

/// Cost breakdown of one history-file write at CONUS scale.
#[derive(Debug, Clone, Default)]
pub struct WriteCost {
    pub phases: Vec<Phase>,
}

impl WriteCost {
    pub fn push(&mut self, name: &'static str, secs: f64) {
        self.phases.push(Phase {
            name,
            secs,
            blocking: true,
        });
    }
    pub fn push_background(&mut self, name: &'static str, secs: f64) {
        self.phases.push(Phase {
            name,
            secs,
            blocking: false,
        });
    }
    /// Application-perceived (blocking) time.
    pub fn perceived(&self) -> f64 {
        self.phases.iter().filter(|p| p.blocking).map(|p| p.secs).sum()
    }
    /// Wall time until data is durable on the final target (incl. drain).
    pub fn durable(&self) -> f64 {
        self.phases.iter().map(|p| p.secs).sum()
    }
    /// Background (non-blocking) virtual seconds — the drain/transfer work
    /// the model claims overlaps the application.  Engines validate this
    /// claim against the *measured* pipeline overlap
    /// ([`crate::adios::engine::DrainStats`]).
    pub fn background(&self) -> f64 {
        self.phases.iter().filter(|p| !p.blocking).map(|p| p.secs).sum()
    }
    /// Virtual seconds hidden from the application (`durable − perceived`).
    pub fn hidden(&self) -> f64 {
        self.durable() - self.perceived()
    }
}

/// Measured degradation of the testbed relative to the model's nominal
/// assumptions, fed back from a running engine (DESIGN.md §17).  Each
/// field is a fraction of the nominal bandwidth actually observed,
/// clamped to `(0, 1]` — the feedback loop only ever *degrades* the
/// model (a store running faster than assumed never forces a replan).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasuredProfile {
    /// Sustained BB→PFS drain bandwidth fraction (NVMe read side).
    pub drain_bw_frac: f64,
    /// PFS write bandwidth fraction (cross-run contention, degraded
    /// disks); scales both direct PFS landings and the drain's PFS leg.
    pub pfs_bw_frac: f64,
    /// Codec compress-throughput fraction (CPU contention on the host).
    pub compress_frac: f64,
}

impl Default for MeasuredProfile {
    fn default() -> Self {
        MeasuredProfile {
            drain_bw_frac: 1.0,
            pfs_bw_frac: 1.0,
            compress_frac: 1.0,
        }
    }
}

impl MeasuredProfile {
    /// Clamp every fraction into `(0, 1]` (degrade-only substitution).
    pub fn clamped(&self) -> MeasuredProfile {
        let c = |f: f64| {
            if f.is_finite() {
                f.clamp(1e-6, 1.0)
            } else {
                1.0
            }
        };
        MeasuredProfile {
            drain_bw_frac: c(self.drain_bw_frac),
            pfs_bw_frac: c(self.pfs_bw_frac),
            compress_frac: c(self.compress_frac),
        }
    }

    /// True when every measurement matches the nominal model (within a
    /// hair) — the healthy-run case where re-planning must be a no-op.
    pub fn is_nominal(&self) -> bool {
        let c = self.clamped();
        c.drain_bw_frac > 0.999 && c.pfs_bw_frac > 0.999 && c.compress_frac > 0.999
    }
}

/// Cost-model facade over a [`HardwareSpec`].
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HardwareSpec,
}

impl CostModel {
    pub fn new(hw: HardwareSpec) -> Self {
        CostModel { hw }
    }

    /// Substitute measured bandwidth fractions into the model: the
    /// returned model scores every landing/drain primitive against the
    /// *observed* testbed instead of the nominal one (DESIGN.md §17).
    /// Nominal fractions return an identical model, so the open-loop
    /// planner path is bit-stable through this call.
    pub fn with_measured(&self, measured: &MeasuredProfile) -> CostModel {
        let m = measured.clamped();
        let mut hw = self.hw.clone();
        hw.pfs_agg_bw *= m.pfs_bw_frac;
        hw.pfs_stream_bw *= m.pfs_bw_frac;
        hw.nvme_read_bw *= m.drain_bw_frac;
        CostModel { hw }
    }

    /// One-time virtual charge of adopting a new plan between steps: a
    /// collective agreement round, plus the MDS creates of a fresh
    /// sub-file layout when the aggregator count (or target) moved.
    /// Charged against the predicted gain so marginal replans never win.
    pub fn t_replan(&self, layout_change: bool, naggs: usize) -> f64 {
        let sync = self.t_collective_sync(1);
        if layout_change {
            sync + self.t_mds_creates(naggs.max(1) + 1)
        } else {
            sync
        }
    }

    // ---- efficiencies -----------------------------------------------------

    /// Concurrent-stream efficiency of the PFS backend.
    pub fn stream_efficiency(&self, streams: usize) -> f64 {
        let knee = self.hw.pfs_thrash_knee;
        if streams <= knee {
            1.0
        } else {
            let excess = (streams - knee) as f64;
            1.0 / (1.0 + self.hw.pfs_thrash_slope * excess / self.hw.pfs_targets as f64)
        }
    }

    /// Byte-range lock efficiency for `writers` collective N-1 writers.
    pub fn lock_efficiency(&self, writers: usize) -> f64 {
        1.0 / (1.0 + self.hw.lock_c * (writers.saturating_sub(1)) as f64)
    }

    // ---- storage primitives -------------------------------------------------

    /// Effective PFS write bandwidth seen by `streams` concurrent
    /// independent streams (no shared-file locks).
    pub fn pfs_bw(&self, streams: usize) -> f64 {
        let per_stream = streams as f64 * self.hw.pfs_stream_bw;
        let agg = self.hw.pfs_agg_bw * self.stream_efficiency(streams);
        per_stream.min(agg).min(self.hw.pfs_ingress_bw)
    }

    /// Time to write `bytes` (virtual) through `streams` independent
    /// streams to the PFS.
    pub fn t_pfs_write(&self, bytes: f64, streams: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.pfs_bw(streams.max(1))
    }

    /// Time to write `bytes` to a *single shared file* by `writers`
    /// collective writers (PnetCDF/MPI-I/O path): lock serialization plus
    /// read-modify-write inflation for unaligned stripes.
    pub fn t_pfs_write_locked(&self, bytes: f64, writers: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let eff = self.lock_efficiency(writers.max(1));
        let bw = self.pfs_bw(writers.max(1)) * eff;
        bytes * self.hw.rmw_inflation / bw
    }

    /// MDS create storm: `n` near-simultaneous file creates.
    pub fn t_mds_creates(&self, n: usize) -> f64 {
        let nf = n as f64;
        nf * self.hw.mds_create_s * (1.0 + nf / self.hw.mds_storm_knee)
    }

    /// Node-local NVMe write: `bytes` split over `nodes` local drives;
    /// nodes proceed in parallel, so the max per-node share bounds time.
    pub fn t_nvme_write(&self, bytes: f64, nodes: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let per_node = bytes / nodes.max(1) as f64;
        per_node / self.hw.nvme_write_bw
    }

    /// Drain `bytes` from `nodes` burst buffers back to the PFS
    /// (background thread): bounded by NVMe read and PFS write.
    pub fn t_bb_drain(&self, bytes: f64, nodes: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let read = bytes / nodes.max(1) as f64 / self.hw.nvme_read_bw;
        let write = self.t_pfs_write(bytes, nodes.max(1));
        read.max(write)
    }

    /// Effective per-run put bandwidth into the shared object space when
    /// `writers` concurrent runs (ensemble members) write to it: each run
    /// is capped by its own client RPC/RDMA pipeline and by a fair share
    /// of the aggregate ingest — no shared append offsets, no seek
    /// thrash (DESIGN.md §13).
    pub fn obj_bw(&self, writers: usize) -> f64 {
        let w = writers.max(1) as f64;
        self.hw.obj_put_bw.min(self.hw.obj_agg_bw / w)
    }

    /// Time for one run to put `bytes` into the object space while
    /// `writers` runs write concurrently.
    pub fn t_obj_put(&self, bytes: f64, writers: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.obj_bw(writers)
    }

    /// Per-object metadata overhead: `objects` independent key inserts.
    /// A flat per-key charge — the KV tier has no directory-lock convoy,
    /// so this does *not* follow the MDS storm formula.
    pub fn t_obj_md(&self, objects: usize) -> f64 {
        objects as f64 * self.hw.obj_md_s
    }

    /// Cross-run contention factor on the PFS for `writers` concurrent
    /// *runs* (ensemble members) sharing one file system: unrelated file
    /// trees interleave seeks, degrading every run by `1 + c·(N−1)` on
    /// top of the per-run stream model.  Multiplies a single-run PFS
    /// write or drain time.
    pub fn cross_run_contention(&self, writers: usize) -> f64 {
        1.0 + self.hw.pfs_cross_run_c * writers.saturating_sub(1) as f64
    }

    /// Read `bytes` from the PFS through `streams` concurrent reader
    /// streams (post-hoc analysis / PFS-side follow): the backend's
    /// bandwidth curve is symmetric with writes at this model's fidelity.
    pub fn t_pfs_read(&self, bytes: f64, streams: usize) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.pfs_bw(streams.max(1))
    }

    /// Read `bytes` from the node-local burst-buffer replicas (`nodes`
    /// drives in parallel — the BB-local follow path, DESIGN.md §11).
    /// While the background drain is still shipping the same sub-files to
    /// the PFS, its reader and the follower's reads contend for each
    /// NVMe's read bandwidth, so the effective rate halves.
    pub fn t_bb_follow_read(&self, bytes: f64, nodes: usize, drain_active: bool) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let share = if drain_active { 0.5 } else { 1.0 };
        bytes / nodes.max(1) as f64 / (self.hw.nvme_read_bw * share)
    }

    /// Virtual seconds from a step leaving the application's buffers to
    /// the first in-situ analysis read of it completing — the metric the
    /// BB-local follow optimizes (fig 9 bench).
    ///
    /// * `bb_follow = true`: the consumer reads the NVMe replica as soon
    ///   as the BB-local index is published, contending with the
    ///   still-running drain.
    /// * `bb_follow = false`: the consumer waits for the PFS copy (the
    ///   drain itself) and then reads it back off the PFS as one stream
    ///   per node-local consumer.
    pub fn time_to_first_analysis(&self, step_bytes: f64, bb_follow: bool) -> f64 {
        let nodes = self.hw.nodes.max(1);
        let land_on_bb = self.t_nvme_write(step_bytes, nodes);
        if bb_follow {
            land_on_bb + self.t_bb_follow_read(step_bytes, nodes, true)
        } else {
            land_on_bb
                + self.t_bb_drain(step_bytes, nodes)
                + self.t_pfs_read(step_bytes, nodes)
        }
    }

    // ---- communication primitives -------------------------------------------

    /// Funnel `bytes` from all ranks to rank 0 (serial-NetCDF gather):
    /// bounded by the root's NIC for remote data plus per-message latency.
    pub fn t_gather_root(&self, bytes: f64, msgs: usize) -> f64 {
        let remote_frac = if self.hw.nodes <= 1 {
            0.0
        } else {
            (self.hw.nodes - 1) as f64 / self.hw.nodes as f64
        };
        let net = bytes * remote_frac / self.hw.link_bw;
        let shm = bytes * (1.0 - remote_frac) / self.hw.shm_bw;
        net + shm + msgs as f64 * self.hw.link_lat_s
    }

    /// Two-phase exchange (all-to-all) of `bytes` total across nodes.
    pub fn t_alltoall(&self, bytes: f64) -> f64 {
        let n = self.hw.nodes as f64;
        if self.hw.nodes <= 1 {
            // Intra-node reshuffle through shared memory.
            return bytes / self.hw.mem_bw;
        }
        // Each node's link carries its share × remote fraction, all links
        // active simultaneously.
        bytes * (n - 1.0) / (n * n) / self.hw.link_bw + bytes / self.hw.mem_bw
    }

    /// Per-variable collective synchronization for two-phase writes.
    pub fn t_collective_sync(&self, nvars: usize) -> f64 {
        let ranks = self.hw.ranks().max(2) as f64;
        nvars as f64 * self.hw.coll_sync_s * ranks.log2()
    }

    /// Aggregation chain: ranks stream their payload to their node-local
    /// aggregator, pipelined with the aggregator's write.  The non-
    /// overlapped cost is the slowest per-aggregator inflow.
    pub fn t_chain_gather(&self, bytes: f64, aggregators: usize) -> f64 {
        let per_agg = bytes / aggregators.max(1) as f64;
        per_agg / self.hw.shm_bw
    }

    /// In-memory buffering of a put (engine copies user data).
    pub fn t_buffer_copy(&self, bytes: f64) -> f64 {
        bytes / self.hw.mem_bw
    }

    /// Stream `bytes` from producer to consumer over the interconnect
    /// (SST data movement, background thread) through a single stream —
    /// the rank-0 funnel's wire.
    pub fn t_stream_transfer(&self, bytes: f64) -> f64 {
        bytes / self.hw.link_bw + self.hw.link_lat_s
    }

    /// Stream `bytes` over `lanes` concurrent producer→consumer
    /// connections (the parallel SST data plane): lanes are charged as
    /// concurrent network streams — aggregators on distinct nodes drive
    /// distinct NICs, so up to `nodes` lanes progress at full link rate in
    /// parallel (extra lanes on the same node share its NIC), plus one
    /// per-message latency for the step's lane batch.
    pub fn t_stream_transfer_lanes(&self, bytes: f64, lanes: usize) -> f64 {
        let parallel = lanes.clamp(1, self.hw.nodes.max(1)) as f64;
        bytes / (self.hw.link_bw * parallel) + self.hw.link_lat_s
    }

    /// Producer egress for a multi-consumer fan-out: every consumer's
    /// stream carries its own (possibly subscription-cropped) copy, so
    /// the wire pays the *sum* of per-consumer bytes; `lanes` concurrent
    /// connections share the producer-side NICs exactly as in
    /// [`Self::t_stream_transfer_lanes`], plus one per-message latency
    /// per consumer stream.  With one full consumer this degenerates to
    /// the single-stream transfer.
    pub fn t_stream_egress(&self, per_consumer_bytes: &[f64], lanes: usize) -> f64 {
        if per_consumer_bytes.is_empty() {
            return 0.0;
        }
        let total: f64 = per_consumer_bytes.iter().sum();
        let parallel = lanes.clamp(1, self.hw.nodes.max(1)) as f64;
        total / (self.hw.link_bw * parallel)
            + self.hw.link_lat_s * per_consumer_bytes.len() as f64
    }

    /// Score direct fan-out (per-lane aggregators ship every consumer's
    /// stream concurrently) against the funnel-and-relay alternative
    /// (gather one full copy at rank 0, then the root re-ships each
    /// consumer's stream through its single NIC).  `step_bytes` is the
    /// full stored step volume — what members actually ship through the
    /// gather/chain fabric in both designs, since subscription cropping
    /// happens at the lane (cropped subscriptions shrink only the wire
    /// egress).  Returns `relay_time / fanout_time`: > 1 means the
    /// fan-out data plane wins, and the advantage grows with consumer
    /// count because the relay serializes every copy on one NIC on top
    /// of the serial gather.
    pub fn fanout_advantage(
        &self,
        step_bytes: f64,
        per_consumer_bytes: &[f64],
        lanes: usize,
    ) -> f64 {
        let total: f64 = per_consumer_bytes.iter().sum();
        if total <= 0.0 || step_bytes <= 0.0 {
            return 1.0;
        }
        let relay =
            self.t_gather_root(step_bytes, self.hw.ranks()) + self.t_stream_transfer(total);
        let fanout = self.t_chain_gather(step_bytes, lanes.max(1))
            + self.t_stream_egress(per_consumer_bytes, lanes);
        relay / fanout
    }

    /// Per-step perceived time of the BP4 sub-file write path: the
    /// node-local chain to `aggregators` sub-file streams plus the
    /// landing write (NVMe burst buffer or PFS).  The canonical scoring
    /// formula of the planner's aggregator sweep
    /// ([`crate::plan::Planner::choose_aggregators`]), consistent with the
    /// engine's per-step charge (`chain` + `write-*` phases).
    pub fn t_bp4_perceived(&self, stored_bytes: f64, aggregators: usize, bb: bool) -> f64 {
        let chain = self.t_chain_gather(stored_bytes, aggregators);
        let write = if bb {
            self.t_nvme_write(stored_bytes, self.hw.nodes.max(1))
        } else {
            self.t_pfs_write(stored_bytes, aggregators)
        };
        chain + write
    }

    /// Per-rank parallel compression: each rank compresses its share at
    /// the measured single-thread codec throughput.
    pub fn t_compress(&self, bytes: f64, codec_bw: f64) -> f64 {
        if codec_bw <= 0.0 {
            return 0.0;
        }
        bytes / self.hw.ranks().max(1) as f64 / codec_bw
    }

    /// Producer-side codec work of a fan-out step with the
    /// content-addressed crop cache (DESIGN.md §14): the lanes compress
    /// each *unique* `(block × box × operator)` crop exactly once, so
    /// the charge takes the deduplicated raw crop volume
    /// (`unique_crop_bytes`) — **independent of consumer count** — split
    /// across the `lanes` aggregators compressing concurrently.  The
    /// naive per-consumer path is this with `unique_crop_bytes`
    /// multiplied by the subscriber count.  The wire itself still pays
    /// per consumer stream ([`Self::t_stream_egress`]).
    pub fn t_fanout_codec(&self, unique_crop_bytes: f64, lanes: usize, codec_bw: f64) -> f64 {
        if codec_bw <= 0.0 || unique_crop_bytes <= 0.0 {
            return 0.0;
        }
        unique_crop_bytes / lanes.clamp(1, self.hw.ranks().max(1)) as f64 / codec_bw
    }

    /// Replay egress for consumers admitted mid-stream by the service
    /// broker (wire v4, DESIGN.md §15): the joiner's first payload is
    /// served from the step's already-compressed crop cache, so no codec
    /// work is re-charged — only the extra wire bytes, shipped through
    /// the same `lanes` producer NICs as the regular fan-out.  Charged as
    /// a background phase: the sender threads ship it while the
    /// application runs ahead.
    pub fn t_admission_replay(&self, replay_bytes: f64, lanes: usize) -> f64 {
        if replay_bytes <= 0.0 {
            return 0.0;
        }
        self.t_stream_egress(&[replay_bytes], lanes)
    }

    /// Re-crop charge when a consumer rescopes its boxed subscription
    /// between steps (DESIGN.md §15): the next boundary's effective
    /// subscription groups are re-keyed, so the rescoped consumers' crops
    /// miss the content-addressed cache once and pay a fresh
    /// `extract_box` + compress pass at the lanes.  Same shape as
    /// [`Self::t_fanout_codec`] over just the rescoped egress volume.
    pub fn t_rescope_recrop(&self, recrop_bytes: f64, lanes: usize, codec_bw: f64) -> f64 {
        self.t_fanout_codec(recrop_bytes, lanes, codec_bw)
    }

    /// One relay hop of the distribution tree (DESIGN.md §16): the relay
    /// receives the producer's single upstream stream, then re-ships each
    /// leaf's copy through its own single NIC.  Both halves are
    /// background work — the model never blocks on a relay — and the
    /// producer's own charge shrinks to *one* lane stream per relay
    /// instead of one per leaf (the egress relief the planner trades this
    /// hop against).
    pub fn t_relay_hop(&self, upstream_bytes: f64, per_consumer_bytes: &[f64]) -> f64 {
        if upstream_bytes <= 0.0 && per_consumer_bytes.is_empty() {
            return 0.0;
        }
        self.t_stream_transfer(upstream_bytes) + self.t_stream_egress(per_consumer_bytes, 1)
    }

    /// Score direct fan-out (one producer lane per consumer) against a
    /// 2-level relay tree with `relays` relay nodes: the producer ships
    /// one stream per *relay* — each carrying the union of that relay's
    /// leaves, modeled as the widest leaf subscription in the group
    /// (leaves assigned round-robin) — and the relays re-serve the
    /// leaves a hop later.  Both designs pay the same node-local chain.
    ///
    /// The basis is the **producer's** step time (the model's blocking
    /// path): the relay's own byte movement runs pipelined one step
    /// behind on the relay's NIC — each tree level's bounded queues
    /// decouple it, and a saturated relay back-pressures only its
    /// subtree, never the producer — so the tree's scored path pays the
    /// producer→relay egress plus one extra store-and-forward link
    /// latency, not the hop's bandwidth (which [`Self::t_relay_hop`]
    /// charges to the relay's own background ledger).  Returns
    /// `direct_time / tree_time`: > 1 means the tree's producer-egress
    /// relief beats its extra hop latency, and the advantage grows with
    /// consumer count because direct egress is linear in consumers while
    /// the tree's producer egress is linear in relays.  `relays == 0`
    /// (or an empty/zero load) scores 1.0 — no tree, no advantage.
    pub fn fanout_advantage_tree(
        &self,
        step_bytes: f64,
        per_consumer_bytes: &[f64],
        lanes: usize,
        relays: usize,
    ) -> f64 {
        let total: f64 = per_consumer_bytes.iter().sum();
        if total <= 0.0 || step_bytes <= 0.0 || relays == 0 {
            return 1.0;
        }
        let chain = self.t_chain_gather(step_bytes, lanes.max(1));
        let direct = chain + self.t_stream_egress(per_consumer_bytes, lanes);
        // Producer → relays: stream g carries the union of the leaves
        // assigned to relay g (round-robin), modeled as the group's
        // widest leaf.
        let mut relay_streams = vec![0.0f64; relays];
        for (i, b) in per_consumer_bytes.iter().enumerate() {
            let g = i % relays;
            relay_streams[g] = relay_streams[g].max(*b);
        }
        let tree =
            chain + self.t_stream_egress(&relay_streams, lanes) + self.hw.link_lat_s;
        direct / tree
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm(nodes: usize) -> CostModel {
        CostModel::new(HardwareSpec::paper_testbed(nodes))
    }

    #[test]
    fn stream_efficiency_monotone_decreasing() {
        let m = cm(8);
        let mut last = 1.0;
        for s in [1, 8, 32, 64, 144, 288] {
            let e = m.stream_efficiency(s);
            assert!(e <= last + 1e-12, "eff not monotone at {s}");
            assert!(e > 0.0 && e <= 1.0);
            last = e;
        }
        assert_eq!(m.stream_efficiency(8), 1.0);
        assert!(m.stream_efficiency(288) < 0.35);
    }

    #[test]
    fn lock_efficiency_shape() {
        let m = cm(8);
        assert_eq!(m.lock_efficiency(1), 1.0);
        assert!(m.lock_efficiency(8) < 0.2);
    }

    #[test]
    fn pfs_bw_single_stream_capped() {
        let m = cm(1);
        assert!((m.pfs_bw(1) - m.hw.pfs_stream_bw).abs() < 1.0);
        // 8 streams reach aggregate.
        assert!((m.pfs_bw(8) - m.hw.pfs_agg_bw).abs() / m.hw.pfs_agg_bw < 0.1);
    }

    #[test]
    fn nvme_scales_with_nodes() {
        let m = cm(8);
        let v = 8e9;
        let t8 = m.t_nvme_write(v, 8);
        let t1 = m.t_nvme_write(v, 1);
        assert!((t1 / t8 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn gather_root_single_node_uses_shm() {
        let m1 = cm(1);
        let m8 = cm(8);
        let b = 1e9;
        // Multi-node funnel is slower per byte? No: shm 6 GB/s < link 12.5,
        // but remote fraction bound by root ingress; both finite + positive.
        assert!(m1.t_gather_root(b, 36) > 0.0);
        assert!(m8.t_gather_root(b, 288) > 0.0);
    }

    #[test]
    fn write_cost_perceived_vs_durable() {
        let mut c = WriteCost::default();
        c.push("write", 1.0);
        c.push_background("drain", 3.0);
        assert_eq!(c.perceived(), 1.0);
        assert_eq!(c.durable(), 4.0);
        assert_eq!(c.background(), 3.0);
        assert_eq!(c.hidden(), 3.0);
    }

    #[test]
    fn lane_transfer_beats_funnel() {
        // One lane degenerates to the single-stream transfer; 8 lanes on
        // 8 nodes cut the wire time ~8x; lane count never hurts.
        let m = cm(8);
        let v = 8e9;
        assert!((m.t_stream_transfer_lanes(v, 1) - m.t_stream_transfer(v)).abs() < 1e-9);
        assert!(m.t_stream_transfer_lanes(v, 8) < m.t_stream_transfer(v) / 4.0);
        let mut last = f64::INFINITY;
        for lanes in [1usize, 2, 4, 8, 16] {
            let t = m.t_stream_transfer_lanes(v, lanes);
            assert!(t <= last + 1e-12, "lanes {lanes} slower than fewer lanes");
            last = t;
        }
        // The blocking side of the step: the rank-0 funnel gather dwarfs
        // the node-local chain to per-lane aggregators (the serial-funnel
        // bottleneck the parallel data plane removes).
        assert!(m.t_gather_root(v, 288) > 2.0 * m.t_chain_gather(v, 8));
    }

    #[test]
    fn egress_degenerates_to_single_stream() {
        let m = cm(8);
        let v = 8e9;
        // One full consumer over one lane == the v2 single-stream charge.
        assert!((m.t_stream_egress(&[v], 1) - m.t_stream_transfer(v)).abs() < 1e-9);
        // One full consumer over 8 lanes == the v2 lane charge.
        assert!(
            (m.t_stream_egress(&[v], 8) - m.t_stream_transfer_lanes(v, 8)).abs() < 1e-9
        );
        // Each extra consumer stream adds wire time (egress is per copy).
        assert!(m.t_stream_egress(&[v, v], 8) > m.t_stream_egress(&[v], 8));
        // A cropped subscription costs less egress than a full one.
        assert!(m.t_stream_egress(&[v, v / 16.0], 8) < m.t_stream_egress(&[v, v], 8));
        assert_eq!(m.t_stream_egress(&[], 8), 0.0);
    }

    #[test]
    fn fanout_beats_funnel_relay_and_grows_with_consumers() {
        let m = cm(8);
        let v = 8e9;
        let a1 = m.fanout_advantage(v, &[v], 8);
        let a3 = m.fanout_advantage(v, &[v, v, v], 8);
        assert!(a1 > 1.0, "fan-out must beat the relay for 1 consumer: {a1:.2}");
        assert!(
            a3 > a1,
            "advantage must grow with consumer count: {a3:.2} vs {a1:.2}"
        );
        // Boxed consumers shrink only the egress terms: the chain/gather
        // stage is still charged with the full step both ways.
        let boxed = m.fanout_advantage(v, &[v / 100.0, v / 100.0], 8);
        assert!(boxed > 0.0 && boxed.is_finite());
        assert_eq!(m.fanout_advantage(v, &[], 8), 1.0);
        assert_eq!(m.fanout_advantage(0.0, &[v], 8), 1.0);
    }

    #[test]
    fn fanout_codec_charges_unique_crops_not_consumers() {
        let m = cm(8);
        let crop = 1e8; // raw bytes of one step's unique crops
        let bw = 0.9e9;
        let one = m.t_fanout_codec(crop, 8, bw);
        assert!(one > 0.0);
        // The frame-cache contract: a thousand subscribers to the same
        // crop set cost exactly what one does — the charge takes unique
        // bytes, so it cannot grow with consumer count at all.  The
        // naive per-consumer path is the same formula over N× the bytes.
        let naive_1000 = m.t_fanout_codec(crop * 1000.0, 8, bw);
        assert!((naive_1000 / one - 1000.0).abs() < 1e-6);
        // More lanes compress unique crops concurrently (up to ranks).
        assert!(m.t_fanout_codec(crop, 16, bw) < one);
        // Zero guards match the t_compress conventions.
        assert_eq!(m.t_fanout_codec(crop, 8, 0.0), 0.0);
        assert_eq!(m.t_fanout_codec(0.0, 8, bw), 0.0);
    }

    #[test]
    fn admission_replay_and_rescope_recrop_shapes() {
        let m = cm(8);
        let v = 1e9;
        let bw = 0.9e9;
        // Replay is one extra consumer stream over the same lanes.
        assert!((m.t_admission_replay(v, 8) - m.t_stream_egress(&[v], 8)).abs() < 1e-12);
        // No joiners, no charge — keeps v3 runs byte-for-byte unchanged.
        assert_eq!(m.t_admission_replay(0.0, 8), 0.0);
        // More lanes ship the replay faster (up to node count).
        assert!(m.t_admission_replay(v, 8) < m.t_admission_replay(v, 1));
        // A rescope pays one fresh codec pass over the rescoped egress,
        // exactly the fan-out codec shape; zero guards match.
        assert!((m.t_rescope_recrop(v, 8, bw) - m.t_fanout_codec(v, 8, bw)).abs() < 1e-12);
        assert_eq!(m.t_rescope_recrop(0.0, 8, bw), 0.0);
        assert_eq!(m.t_rescope_recrop(v, 8, 0.0), 0.0);
    }

    #[test]
    fn relay_hop_and_tree_advantage_shapes() {
        let m = cm(8);
        let v = 8e9;
        // One hop = receive the upstream stream + re-serve the leaves on
        // one NIC — bit-equal to its two primitives.
        let leaves = [v, v, v / 16.0];
        assert!(
            (m.t_relay_hop(v, &leaves)
                - (m.t_stream_transfer(v) + m.t_stream_egress(&leaves, 1)))
            .abs()
                < 1e-12
        );
        // No upstream, no leaves, no charge.
        assert_eq!(m.t_relay_hop(0.0, &[]), 0.0);
        // No relays (or no load) scores neutral — direct runs unchanged.
        assert_eq!(m.fanout_advantage_tree(v, &[v, v], 8, 0), 1.0);
        assert_eq!(m.fanout_advantage_tree(0.0, &[v], 8, 2), 1.0);
        assert_eq!(m.fanout_advantage_tree(v, &[], 8, 2), 1.0);
        // The tree's case: direct egress is linear in consumers, the
        // tree's producer egress is linear in relays — so the advantage
        // must grow with consumer count at fixed relay count...
        let full8: Vec<f64> = vec![v; 8];
        let full32: Vec<f64> = vec![v; 32];
        let a8 = m.fanout_advantage_tree(v, &full8, 8, 2);
        let a32 = m.fanout_advantage_tree(v, &full32, 8, 2);
        assert!(
            a32 > a8,
            "tree advantage must grow with consumers: {a32:.2} vs {a8:.2}"
        );
        // ...and clearly beat direct in the tens (ROADMAP direction 2).
        assert!(a32 > 1.0, "32 full consumers behind 2 relays: {a32:.2}");
        // A single consumer never justifies the extra hop.
        assert!(m.fanout_advantage_tree(v, &[v], 8, 1) < 1.0);
    }

    #[test]
    fn bb_follow_first_analysis_strictly_below_pfs_follow() {
        // Acceptance gate of the tiered-follow PR: reading the fastest
        // tier the data has reached must beat waiting for the drain at
        // every paper node count — and the drain contention charge must
        // not erase the win.
        let v = 8e9;
        for nodes in [1usize, 2, 4, 8] {
            let m = cm(nodes);
            let bb = m.time_to_first_analysis(v, true);
            let pfs = m.time_to_first_analysis(v, false);
            assert!(
                bb < pfs,
                "{nodes} nodes: BB-follow {bb:.2}s !< PFS-follow {pfs:.2}s"
            );
            // Contended BB reads are slower than uncontended, but still on
            // the NVMe latency scale.
            let contended = m.t_bb_follow_read(v, nodes, true);
            let free = m.t_bb_follow_read(v, nodes, false);
            assert!(contended > free && contended <= 2.0 * free + 1e-9);
        }
        // Zero-byte guards.
        let m = cm(8);
        assert_eq!(m.t_pfs_read(0.0, 4), 0.0);
        assert_eq!(m.t_bb_follow_read(0.0, 4, true), 0.0);
    }

    #[test]
    fn bp4_perceived_matches_paper_fig4_shape() {
        // The planner's sweep formula must reproduce fig 4: at 1 node a
        // single stream cannot saturate BeeGFS (more aggregators win); at
        // 8 nodes 288 streams thrash the 8 targets (36/node loses to
        // 1/node), and the NVMe landing is aggregator-count-insensitive.
        let v = 8e9;
        let m1 = cm(1);
        assert!(m1.t_bp4_perceived(v, 8, false) < m1.t_bp4_perceived(v, 1, false) / 2.0);
        let m8 = cm(8);
        assert!(m8.t_bp4_perceived(v, 288, false) > m8.t_bp4_perceived(v, 8, false));
        let bb1 = m8.t_bp4_perceived(v, 8, true);
        let bb36 = m8.t_bp4_perceived(v, 288, true);
        assert!((bb1 - bb36).abs() < bb1 * 0.2, "NVMe path ~flat in aggs");
        assert!(bb1 < m8.t_bp4_perceived(v, 8, false), "BB beats PFS");
    }

    #[test]
    fn object_store_charges() {
        let m = cm(8);
        // A single writer is capped by its own pipeline, not the aggregate.
        assert_eq!(m.obj_bw(1), m.hw.obj_put_bw);
        // Many writers share the aggregate fairly.
        let w32 = m.obj_bw(32);
        assert!((w32 - m.hw.obj_agg_bw / 32.0).abs() / w32 < 1e-9);
        let v = 8e9;
        assert!(m.t_obj_put(v, 32) > m.t_obj_put(v, 1));
        assert_eq!(m.t_obj_put(0.0, 4), 0.0);
        assert!((m.t_obj_md(1000) - 1000.0 * m.hw.obj_md_s).abs() < 1e-12);
        assert_eq!(m.cross_run_contention(1), 1.0);
        assert!(m.cross_run_contention(8) > 5.0);
    }

    #[test]
    fn object_advantage_grows_with_writer_count() {
        // The fig 11 story at model level: one run on the paper PFS vs the
        // object space is a modest win, but at ensemble scale the shared
        // PFS degrades with cross-run contention much faster than the
        // object space's fair-share ingest divides.
        let m = cm(8);
        let v = 8e9;
        let mut last = 0.0;
        for writers in [1usize, 2, 4, 8, 16] {
            let pfs = m.t_pfs_write(v, 8) * m.cross_run_contention(writers);
            let obj = m.t_obj_put(v, writers) + m.t_obj_md(288 * 2);
            let adv = pfs / obj;
            assert!(
                adv > last,
                "advantage must grow with N: {adv:.2} at {writers} writers vs {last:.2}"
            );
            last = adv;
        }
        assert!(last > 8.0, "object advantage at 16 writers: {last:.1}");
    }

    #[test]
    fn measured_profile_substitution_degrades_only_what_it_names() {
        let m = cm(8);
        // Nominal fractions are the identity: the open-loop planner path
        // must be bit-stable through with_measured.
        let nominal = m.with_measured(&MeasuredProfile::default());
        assert_eq!(nominal.hw.pfs_agg_bw, m.hw.pfs_agg_bw);
        assert_eq!(nominal.hw.nvme_read_bw, m.hw.nvme_read_bw);
        assert!(MeasuredProfile::default().is_nominal());
        // A PFS collapse slows direct landings AND the drain's PFS leg,
        // but leaves the object space untouched.
        let collapsed = m.with_measured(&MeasuredProfile {
            pfs_bw_frac: 0.25,
            ..MeasuredProfile::default()
        });
        let v = 8e9;
        assert!(collapsed.t_pfs_write(v, 8) > 3.0 * m.t_pfs_write(v, 8));
        assert!(collapsed.t_bb_drain(v, 8) > m.t_bb_drain(v, 8));
        assert_eq!(collapsed.t_obj_put(v, 1), m.t_obj_put(v, 1));
        // Fractions above 1 (or garbage) clamp back to nominal: the loop
        // never *speeds up* the model.
        let sped = m.with_measured(&MeasuredProfile {
            pfs_bw_frac: 4.0,
            drain_bw_frac: f64::NAN,
            compress_frac: 1.0,
        });
        assert_eq!(sped.hw.pfs_agg_bw, m.hw.pfs_agg_bw);
        assert_eq!(sped.hw.nvme_read_bw, m.hw.nvme_read_bw);
    }

    #[test]
    fn replan_charge_is_small_but_nonzero() {
        let m = cm(8);
        let knob_only = m.t_replan(false, 8);
        let layout = m.t_replan(true, 8);
        assert!(knob_only > 0.0);
        assert!(layout > knob_only, "a layout change must cost extra");
        // The charge is a between-steps hiccup, not a step's worth of
        // I/O: far below one CONUS step on any target.
        assert!(layout < 1.0, "replan charge {layout:.3}s too large");
    }

    #[test]
    fn paper_scale_sanity_pnetcdf_vs_adios2() {
        // Emergent-shape guard: at 8 nodes a CONUS-scale (8 GB) shared-file
        // collective write must be ~an order of magnitude slower than 8
        // independent sub-file streams (paper Fig 1 / Table I).
        let m = cm(8);
        let v = 8e9;
        let pnetcdf = m.t_pfs_write_locked(v, 8) + m.t_collective_sync(170) + m.t_alltoall(v);
        let adios2 = m.t_pfs_write(v, 8) + m.t_chain_gather(v, 8);
        assert!(
            pnetcdf / adios2 > 6.0,
            "expected ≥6x gap, got {:.1} ({pnetcdf:.1}s vs {adios2:.1}s)",
            pnetcdf / adios2
        );
        // And the gap must *grow* with node count (rising PnetCDF trend).
        let m1 = cm(1);
        let p1 = m1.t_pfs_write_locked(v, 1) + m1.t_collective_sync(170) + m1.t_alltoall(v);
        assert!(pnetcdf > p1, "PnetCDF should degrade as nodes increase");
    }
}
