//! Domain decomposition: the WRF-style 2-D block split of the global grid
//! over MPI ranks.

use crate::{Error, Result};

/// A py × px processor grid over an (ny, nx) domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decomp {
    pub ny: usize,
    pub nx: usize,
    pub py: usize,
    pub px: usize,
}

impl Decomp {
    /// Build a decomposition; patch sizes must divide evenly (the AOT
    /// model is compiled for a fixed patch shape).
    pub fn new(ny: usize, nx: usize, py: usize, px: usize) -> Result<Decomp> {
        if py == 0 || px == 0 || ny == 0 || nx == 0 {
            return Err(Error::model("decomposition dims must be positive"));
        }
        if ny % py != 0 || nx % px != 0 {
            return Err(Error::model(format!(
                "grid {ny}x{nx} not divisible by processor grid {py}x{px}"
            )));
        }
        Ok(Decomp { ny, nx, py, px })
    }

    /// Pick the most-square processor grid for `ranks` that divides the
    /// domain evenly (WRF's default factorization strategy).
    pub fn auto(ny: usize, nx: usize, ranks: usize) -> Result<Decomp> {
        let mut best: Option<Decomp> = None;
        for py in 1..=ranks {
            if ranks % py != 0 {
                continue;
            }
            let px = ranks / py;
            if ny % py != 0 || nx % px != 0 {
                continue;
            }
            let d = Decomp { ny, nx, py, px };
            let aspect = |d: &Decomp| {
                let a = (d.ny / d.py) as f64 / (d.nx / d.px) as f64;
                if a < 1.0 {
                    1.0 / a
                } else {
                    a
                }
            };
            match &best {
                Some(b) if aspect(b) <= aspect(&d) => {}
                _ => best = Some(d),
            }
        }
        best.ok_or_else(|| {
            Error::model(format!(
                "no processor grid for {ranks} ranks divides {ny}x{nx}"
            ))
        })
    }

    pub fn ranks(&self) -> usize {
        self.py * self.px
    }

    /// Patch shape (nyp, nxp).
    pub fn patch(&self) -> (usize, usize) {
        (self.ny / self.py, self.nx / self.px)
    }

    /// Rank → (iy, ix) processor coordinates (row-major).
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        (rank / self.px, rank % self.px)
    }

    pub fn rank_of(&self, iy: usize, ix: usize) -> usize {
        (iy % self.py) * self.px + (ix % self.px)
    }

    /// Periodic neighbours (north, south, west, east) of a rank.
    /// North = +y direction.
    pub fn neighbors(&self, rank: usize) -> [usize; 4] {
        let (iy, ix) = self.coords(rank);
        [
            self.rank_of(iy + 1, ix),
            self.rank_of(iy + self.py - 1, ix),
            self.rank_of(iy, ix + self.px - 1),
            self.rank_of(iy, ix + 1),
        ]
    }

    /// Global (start_y, start_x) of a rank's patch.
    pub fn origin(&self, rank: usize) -> (usize, usize) {
        let (iy, ix) = self.coords(rank);
        let (nyp, nxp) = self.patch();
        (iy * nyp, ix * nxp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_2x2() {
        let d = Decomp::new(192, 192, 2, 2).unwrap();
        assert_eq!(d.patch(), (96, 96));
        assert_eq!(d.coords(3), (1, 1));
        assert_eq!(d.origin(3), (96, 96));
        assert_eq!(d.rank_of(1, 1), 3);
    }

    #[test]
    fn auto_prefers_square_patches() {
        let d = Decomp::auto(192, 192, 4).unwrap();
        assert_eq!((d.py, d.px), (2, 2));
        let d16 = Decomp::auto(192, 192, 16).unwrap();
        assert_eq!((d16.py, d16.px), (4, 4));
    }

    #[test]
    fn auto_rectangular_domain() {
        let d = Decomp::auto(288, 576, 8).unwrap();
        assert_eq!(d.ranks(), 8);
        let (nyp, nxp) = d.patch();
        assert_eq!(nyp * d.py, 288);
        assert_eq!(nxp * d.px, 576);
    }

    #[test]
    fn neighbors_periodic() {
        let d = Decomp::new(8, 8, 2, 2).unwrap();
        // rank 0 at (0,0): north=(1,0)=2, south=(1,0)=2 (wrap), west=(0,1)=1, east=1
        assert_eq!(d.neighbors(0), [2, 2, 1, 1]);
        let d3 = Decomp::new(9, 9, 3, 3).unwrap();
        assert_eq!(d3.neighbors(4), [7, 1, 3, 5]); // center rank
        assert_eq!(d3.neighbors(0), [3, 6, 2, 1]);
    }

    #[test]
    fn indivisible_rejected() {
        assert!(Decomp::new(10, 10, 3, 1).is_err());
        assert!(Decomp::auto(7, 7, 4).is_err());
    }
}
