//! WRF history-variable registry.
//!
//! WRF's history stream carries on the order of one to two hundred named
//! fields per frame (paper §IV: "sometimes over 200").  The I/O behaviour
//! the paper measures depends on that long tail of named 2-D/3-D arrays —
//! per-variable API calls, per-variable metadata, many small-to-medium
//! payloads — so the registry reproduces a realistic WRF-ARW variable set
//! with real WRF names/staggering, each mapped to a source expression over
//! the five prognostic model fields (DESIGN.md §Substitutions).
//!
//! Sources keep the data *physically meaningful* (smooth, correlated,
//! dimensionally sensible) so compression ratios in Fig 5/6 are honest.

use crate::util::rng::Rng;

/// Prognostic field indices in the model state (mirrors
/// `python/compile/model.FIELDS`).
pub const F_H: usize = 0;
pub const F_U: usize = 1;
pub const F_V: usize = 2;
pub const F_TH: usize = 3;
pub const F_QV: usize = 4;

/// How a registry variable's data is produced from the rank's patch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Source {
    /// Affine map of a prognostic 3-D field: `a * field + b`.
    State3d { field: usize, a: f32, b: f32 },
    /// Surface level (z = 0) of a prognostic field, affine-mapped.
    Surface { field: usize, a: f32, b: f32 },
    /// Static terrain-like 2-D field, deterministic in global coords.
    Terrain { seed: u64, amp: f32, base: f32 },
    /// Vertical-coordinate profile broadcast over the patch (3-D).
    Profile { base: f32, lapse: f32 },
}

/// One history variable.
#[derive(Debug, Clone)]
pub struct VarSpec {
    pub name: &'static str,
    pub is_3d: bool,
    pub source: Source,
}

/// The WRF-ARW-like history variable set.
///
/// 3-D fields use the model's `nz` levels; 2-D fields are single planes.
pub fn wrf_history_vars() -> Vec<VarSpec> {
    use Source::*;
    let mut v = Vec::new();
    let s3 = |name, field, a, b| VarSpec {
        name,
        is_3d: true,
        source: State3d { field, a, b },
    };
    let s2 = |name, field, a, b| VarSpec {
        name,
        is_3d: false,
        source: Surface { field, a, b },
    };
    let terrain = |name, seed, amp, base| VarSpec {
        name,
        is_3d: false,
        source: Terrain { seed, amp, base },
    };
    let prof = |name, base, lapse| VarSpec {
        name,
        is_3d: true,
        source: Profile { base, lapse },
    };

    // ---- dynamics (3-D) ---------------------------------------------------
    v.push(s3("U", F_U, 1.0, 0.0));
    v.push(s3("V", F_V, 1.0, 0.0));
    v.push(s3("W", F_V, 0.05, 0.0));
    v.push(s3("T", F_TH, 1.0, -300.0)); // perturbation potential temp
    v.push(s3("THM", F_TH, 1.0, -290.0));
    v.push(s3("PH", F_H, 50.0, 0.0)); // perturbation geopotential
    v.push(prof("PHB", 3000.0, 2500.0)); // base-state geopotential
    v.push(s3("P", F_H, 800.0, -800.0)); // perturbation pressure
    v.push(prof("PB", 95000.0, -8000.0)); // base-state pressure
    v.push(prof("T_INIT", 290.0, 3.0));
    v.push(s3("AL", F_H, -0.02, 0.85));
    v.push(prof("ALB", 0.80, 0.06));
    // ---- moisture / microphysics (3-D) ------------------------------------
    v.push(s3("QVAPOR", F_QV, 1.0, 0.0));
    v.push(s3("QCLOUD", F_QV, 0.10, 0.0));
    v.push(s3("QRAIN", F_QV, 0.02, 0.0));
    v.push(s3("QICE", F_QV, 0.01, 0.0));
    v.push(s3("QSNOW", F_QV, 0.005, 0.0));
    v.push(s3("QGRAUP", F_QV, 0.002, 0.0));
    v.push(s3("CLDFRA", F_QV, 30.0, 0.0));
    // ---- turbulence / radiation tendencies (3-D) ---------------------------
    v.push(s3("TKE_PBL", F_U, 0.3, 0.4));
    v.push(s3("EL_PBL", F_U, 12.0, 25.0));
    v.push(s3("EXCH_H", F_V, 8.0, 15.0));
    v.push(s3("RTHRATEN", F_TH, 1e-5, 0.0));
    v.push(s3("RTHBLTEN", F_TH, 5e-6, 0.0));
    v.push(s3("RQVBLTEN", F_QV, 1e-6, 0.0));
    v.push(s3("RUBLTEN", F_U, 1e-5, 0.0));
    v.push(s3("RVBLTEN", F_V, 1e-5, 0.0));
    v.push(s3("H_DIABATIC", F_TH, 2e-5, 0.0));
    // ---- surface / diagnostics (2-D) ---------------------------------------
    v.push(s2("T2", F_TH, 1.0, -5.0));
    v.push(s2("TH2", F_TH, 1.0, -4.0));
    v.push(s2("Q2", F_QV, 0.9, 0.0));
    v.push(s2("U10", F_U, 0.8, 0.0));
    v.push(s2("V10", F_V, 0.8, 0.0));
    v.push(s2("PSFC", F_H, 900.0, 95000.0));
    v.push(s2("TSK", F_TH, 1.05, -8.0));
    v.push(s2("SST", F_TH, 0.95, 2.0));
    v.push(s2("OLR", F_TH, 0.8, -10.0));
    v.push(s2("PBLH", F_U, 400.0, 800.0));
    v.push(s2("HFX", F_U, 120.0, 40.0));
    v.push(s2("QFX", F_QV, 20.0, 0.0));
    v.push(s2("LH", F_QV, 8000.0, 10.0));
    v.push(s2("UST", F_U, 0.2, 0.3));
    v.push(s2("RAINC", F_QV, 400.0, 0.0));
    v.push(s2("RAINNC", F_QV, 900.0, 0.0));
    v.push(s2("SNOWNC", F_QV, 60.0, 0.0));
    v.push(s2("GRAUPELNC", F_QV, 25.0, 0.0));
    v.push(s2("REFL_10CM", F_QV, 1500.0, -20.0));
    v.push(s2("SWDOWN", F_H, 300.0, 300.0));
    v.push(s2("GLW", F_TH, 1.1, 30.0));
    v.push(s2("GSW", F_H, 250.0, 220.0));
    v.push(s2("ALBEDO", F_H, 0.02, 0.15));
    v.push(s2("EMISS", F_H, 0.01, 0.95));
    v.push(s2("CANWAT", F_QV, 30.0, 0.0));
    v.push(s2("SMOIS_SFC", F_QV, 12.0, 0.25));
    v.push(s2("TSLB_SFC", F_TH, 0.9, 6.0));
    // ---- static fields (2-D, terrain-derived) -------------------------------
    v.push(terrain("HGT", 11, 800.0, 350.0));
    v.push(terrain("LANDMASK", 13, 0.5, 0.5));
    v.push(terrain("LU_INDEX", 17, 8.0, 12.0));
    v.push(terrain("XLAT", 19, 8.0, 40.0));
    v.push(terrain("XLONG", 23, 15.0, -97.0));
    v.push(terrain("MAPFAC_M", 29, 0.02, 1.0));
    v.push(terrain("F_CORIOLIS", 31, 2e-5, 9e-5));
    v.push(terrain("SINALPHA", 37, 0.05, 0.0));
    v.push(terrain("COSALPHA", 41, 0.05, 1.0));
    v.push(terrain("E_CORIOLIS", 43, 1e-5, 5e-5));
    v
}

impl VarSpec {
    /// Materialize this variable for one rank.
    ///
    /// `patch` is the rank's interior state `(nf, nz, nyp, nxp)` flattened;
    /// `origin` its global (y0, x0); `gny/gnx` the global grid (for
    /// deterministic terrain).  Returns row-major data, `nz` planes for 3-D
    /// variables or one plane for 2-D.
    #[allow(clippy::too_many_arguments)]
    pub fn materialize(
        &self,
        patch: &[f32],
        nf: usize,
        nz: usize,
        nyp: usize,
        nxp: usize,
        origin: (usize, usize),
        gny: usize,
        gnx: usize,
    ) -> Vec<f32> {
        debug_assert_eq!(patch.len(), nf * nz * nyp * nxp);
        let plane = nyp * nxp;
        let fplane = nz * plane;
        match self.source {
            Source::State3d { field, a, b } => patch[field * fplane..(field + 1) * fplane]
                .iter()
                .map(|&x| a * x + b)
                .collect(),
            Source::Surface { field, a, b } => patch
                [field * fplane..field * fplane + plane]
                .iter()
                .map(|&x| a * x + b)
                .collect(),
            Source::Profile { base, lapse } => {
                let mut out = Vec::with_capacity(fplane);
                for z in 0..nz {
                    let v = base + lapse * z as f32;
                    out.extend(std::iter::repeat(v).take(plane));
                }
                out
            }
            Source::Terrain { seed, amp, base } => {
                // Deterministic smooth bumps in *global* coordinates so
                // patches tile seamlessly across ranks.
                let mut rng = Rng::new(seed);
                let nb = 6;
                let bumps: Vec<(f32, f32, f32, f32)> = (0..nb)
                    .map(|_| {
                        (
                            rng.uniform(0.0, 1.0),
                            rng.uniform(0.0, 1.0),
                            rng.uniform(0.5, 1.0),
                            rng.uniform(0.05, 0.15),
                        )
                    })
                    .collect();
                let (y0, x0) = origin;
                let mut out = Vec::with_capacity(plane);
                for j in 0..nyp {
                    let gy = (y0 + j) as f32 / gny as f32;
                    for i in 0..nxp {
                        let gx = (x0 + i) as f32 / gnx as f32;
                        let mut h = 0.0;
                        for &(cx, cy, a, w) in &bumps {
                            let r2 = (gx - cx) * (gx - cx) + (gy - cy) * (gy - cy);
                            h += a * (-r2 / (2.0 * w * w)).exp();
                        }
                        out.push(base + amp * h);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_patch(nf: usize, nz: usize, nyp: usize, nxp: usize) -> Vec<f32> {
        (0..nf * nz * nyp * nxp).map(|i| i as f32 * 0.001).collect()
    }

    #[test]
    fn registry_has_wrf_scale_variable_count() {
        let vars = wrf_history_vars();
        assert!(vars.len() >= 60, "only {} vars", vars.len());
        let n3d = vars.iter().filter(|v| v.is_3d).count();
        assert!(n3d >= 20, "only {n3d} 3-D vars");
        // Unique names.
        let mut names: Vec<_> = vars.iter().map(|v| v.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), vars.len());
    }

    #[test]
    fn sizes_match_kind() {
        let (nf, nz, nyp, nxp) = (5, 3, 8, 10);
        let patch = fake_patch(nf, nz, nyp, nxp);
        for v in wrf_history_vars() {
            let data = v.materialize(&patch, nf, nz, nyp, nxp, (0, 0), 16, 20);
            let expect = if v.is_3d { nz * nyp * nxp } else { nyp * nxp };
            assert_eq!(data.len(), expect, "{}", v.name);
            assert!(data.iter().all(|x| x.is_finite()), "{}", v.name);
        }
    }

    #[test]
    fn terrain_tiles_seamlessly() {
        // Two horizontally adjacent patches must agree along the seam.
        let spec = VarSpec {
            name: "HGT",
            is_3d: false,
            source: Source::Terrain { seed: 11, amp: 800.0, base: 350.0 },
        };
        let patch = fake_patch(5, 1, 4, 4);
        let whole_patch = fake_patch(5, 1, 4, 8);
        let left = spec.materialize(&patch, 5, 1, 4, 4, (0, 0), 4, 8);
        let right = spec.materialize(&patch, 5, 1, 4, 4, (0, 4), 4, 8);
        let whole = spec.materialize(&whole_patch, 5, 1, 4, 8, (0, 0), 4, 8);
        // The two half-domain patches must tile to exactly the whole-domain
        // evaluation (terrain is a function of global coordinates only).
        for j in 0..4 {
            for i in 0..4 {
                assert_eq!(left[j * 4 + i], whole[j * 8 + i], "left ({j},{i})");
                assert_eq!(right[j * 4 + i], whole[j * 8 + 4 + i], "right ({j},{i})");
            }
        }
        // And deterministic.
        let again = spec.materialize(&patch, 5, 1, 4, 4, (0, 0), 4, 8);
        assert_eq!(left, again);
    }

    #[test]
    fn state3d_affine() {
        let (nf, nz, nyp, nxp) = (5, 2, 2, 2);
        let patch = fake_patch(nf, nz, nyp, nxp);
        let spec = VarSpec {
            name: "T",
            is_3d: true,
            source: Source::State3d { field: F_TH, a: 2.0, b: 1.0 },
        };
        let d = spec.materialize(&patch, nf, nz, nyp, nxp, (0, 0), 4, 4);
        let base = F_TH * nz * nyp * nxp;
        assert_eq!(d[0], 2.0 * patch[base] + 1.0);
    }
}
