//! Per-rank model state: the padded patch, halo exchange, and the initial
//! condition.
//!
//! The Rust initial condition mirrors `python/compile/model.py`'s
//! `initial_global_state` qualitatively (zonal jet + gaussian anomalies,
//! θ gradient, moist blobs) and is evaluated in *global* coordinates so
//! patches tile seamlessly regardless of the decomposition.

use crate::cluster::Comm;
use crate::model::decomp::Decomp;
use crate::util::rng::Rng;
use crate::Result;

/// Prognostic field count (mirrors `python/compile/model.FIELDS`).
pub const NF: usize = 5;

/// Per-rank padded state.
#[derive(Debug, Clone)]
pub struct RankState {
    pub nf: usize,
    pub nz: usize,
    pub nyp: usize,
    pub nxp: usize,
    pub halo: usize,
    /// `(nf, nz, nyp+2h, nxp+2h)` row-major.
    pub padded: Vec<f32>,
}

impl RankState {
    pub fn ypad(&self) -> usize {
        self.nyp + 2 * self.halo
    }
    pub fn xpad(&self) -> usize {
        self.nxp + 2 * self.halo
    }

    #[inline]
    pub fn idx(&self, f: usize, z: usize, y: usize, x: usize) -> usize {
        ((f * self.nz + z) * self.ypad() + y) * self.xpad() + x
    }

    /// Initial condition for `rank` of `decomp` with `nz` levels.
    pub fn init(decomp: &Decomp, rank: usize, nz: usize, halo: usize, seed: u64) -> RankState {
        let (nyp, nxp) = decomp.patch();
        let (y0, x0) = decomp.origin(rank);
        let mut st = RankState {
            nf: NF,
            nz,
            nyp,
            nxp,
            halo,
            padded: vec![0.0; NF * nz * (nyp + 2 * halo) * (nxp + 2 * halo)],
        };
        // Deterministic global anomaly set shared by all ranks.
        let mut rng = Rng::new(seed);
        let nb = 5;
        let bumps: Vec<(f32, f32, f32, f32)> = (0..nb)
            .map(|_| {
                (
                    rng.uniform(0.0, 1.0),
                    rng.uniform(0.0, 1.0),
                    rng.uniform(0.5, 1.0),
                    rng.uniform(0.05, 0.12),
                )
            })
            .collect();
        let gauss = |gx: f32, gy: f32, scale: f32| -> f32 {
            let mut v = 0.0;
            for &(cx, cy, a, w) in &bumps {
                let r2 = (gx - cx) * (gx - cx) + (gy - cy) * (gy - cy);
                v += a * (-r2 / (2.0 * w * w * scale * scale)).exp();
            }
            v
        };
        for z in 0..nz {
            let lev = 1.0 - 0.08 * z as f32;
            for j in 0..nyp {
                let gy = (y0 + j) as f32 / decomp.ny as f32;
                for i in 0..nxp {
                    let gx = (x0 + i) as f32 / decomp.nx as f32;
                    let y = j + halo;
                    let x = i + halo;
                    let b = gauss(gx, gy, 1.0);
                    let h = 1.0 + 0.1 * b * lev;
                    let u = 0.5 * (2.0 * std::f32::consts::PI * gy).sin() * lev
                        + 0.05 * gauss(gx, gy, 1.4);
                    let v = 0.05 * gauss(gy, gx, 1.2);
                    let th = 280.0 + 30.0 * gy + 5.0 * b + 2.0 * z as f32;
                    let qv = (0.01 * gauss(gx, gy, 0.7)).max(0.0);
                    let vals = [h, u, v, th, qv];
                    for (f, &val) in vals.iter().enumerate() {
                        let k = st.idx(f, z, y, x);
                        st.padded[k] = val;
                    }
                }
            }
        }
        st
    }

    /// Extract the interior `(nf, nz, nyp, nxp)`.
    pub fn interior(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.nf * self.nz * self.nyp * self.nxp);
        for f in 0..self.nf {
            for z in 0..self.nz {
                for j in 0..self.nyp {
                    let base = self.idx(f, z, j + self.halo, self.halo);
                    out.extend_from_slice(&self.padded[base..base + self.nxp]);
                }
            }
        }
        out
    }

    /// Replace the interior from a `(nf, nz, nyp, nxp)` buffer.
    pub fn set_interior(&mut self, interior: &[f32]) {
        assert_eq!(interior.len(), self.nf * self.nz * self.nyp * self.nxp);
        let mut src = 0;
        for f in 0..self.nf {
            for z in 0..self.nz {
                for j in 0..self.nyp {
                    let base = self.idx(f, z, j + self.halo, self.halo);
                    self.padded[base..base + self.nxp]
                        .copy_from_slice(&interior[src..src + self.nxp]);
                    src += self.nxp;
                }
            }
        }
    }

    /// Periodic halo exchange with the rank's decomposition neighbours.
    ///
    /// Two phases (x strips, then y strips over the full padded width) so
    /// corners are filled — the standard structured-grid trick.  Returns
    /// the bytes this rank sent (for cost accounting).
    pub fn halo_exchange(
        &mut self,
        comm: &mut Comm,
        decomp: &Decomp,
        tag_base: u64,
    ) -> Result<u64> {
        let h = self.halo;
        let (ypad, xpad) = (self.ypad(), self.xpad());
        let [north, south, west, east] = decomp.neighbors(comm.rank());
        let mut sent = 0u64;

        // ---- X phase: interior rows only -----------------------------------
        // east edge -> east neighbour's west halo; west edge -> west's east.
        let pack_x = |st: &RankState, x_from: usize| {
            let mut buf = Vec::with_capacity(st.nf * st.nz * st.nyp * h);
            for f in 0..st.nf {
                for z in 0..st.nz {
                    for j in 0..st.nyp {
                        // h columns are contiguous in x: bulk copy.
                        let base = st.idx(f, z, j + h, x_from);
                        buf.extend_from_slice(&st.padded[base..base + h]);
                    }
                }
            }
            buf
        };
        let east_edge = pack_x(self, xpad - 2 * h); // interior columns at east
        let west_edge = pack_x(self, h);
        sent += (east_edge.len() + west_edge.len()) as u64 * 4;
        comm.send(east, tag_base, crate::util::f32_slice_as_bytes(&east_edge).to_vec())?;
        comm.send(west, tag_base + 1, crate::util::f32_slice_as_bytes(&west_edge).to_vec())?;
        let from_west = crate::util::bytes_to_f32_vec(&comm.recv(west, tag_base)?)?;
        let from_east = crate::util::bytes_to_f32_vec(&comm.recv(east, tag_base + 1)?)?;
        let unpack_x = |st: &mut RankState, x_to: usize, buf: &[f32]| {
            let mut k = 0;
            for f in 0..st.nf {
                for z in 0..st.nz {
                    for j in 0..st.nyp {
                        for dx in 0..h {
                            let idx = st.idx(f, z, j + h, x_to + dx);
                            st.padded[idx] = buf[k];
                            k += 1;
                        }
                    }
                }
            }
        };
        unpack_x(self, 0, &from_west); // west halo
        unpack_x(self, xpad - h, &from_east); // east halo

        // ---- Y phase: full padded width (fills corners) --------------------
        let pack_y = |st: &RankState, y_from: usize| {
            let mut buf = Vec::with_capacity(st.nf * st.nz * h * xpad);
            for f in 0..st.nf {
                for z in 0..st.nz {
                    for dy in 0..h {
                        let base = st.idx(f, z, y_from + dy, 0);
                        buf.extend_from_slice(&st.padded[base..base + xpad]);
                    }
                }
            }
            buf
        };
        let north_edge = pack_y(self, ypad - 2 * h); // interior rows at north
        let south_edge = pack_y(self, h);
        sent += (north_edge.len() + south_edge.len()) as u64 * 4;
        comm.send(north, tag_base + 2, crate::util::f32_slice_as_bytes(&north_edge).to_vec())?;
        comm.send(south, tag_base + 3, crate::util::f32_slice_as_bytes(&south_edge).to_vec())?;
        let from_south = crate::util::bytes_to_f32_vec(&comm.recv(south, tag_base + 2)?)?;
        let from_north = crate::util::bytes_to_f32_vec(&comm.recv(north, tag_base + 3)?)?;
        let unpack_y = |st: &mut RankState, y_to: usize, buf: &[f32]| {
            let mut k = 0;
            for f in 0..st.nf {
                for z in 0..st.nz {
                    for dy in 0..h {
                        let base = st.idx(f, z, y_to + dy, 0);
                        st.padded[base..base + xpad].copy_from_slice(&buf[k..k + xpad]);
                        k += xpad;
                    }
                }
            }
        };
        unpack_y(self, 0, &from_south); // south halo
        unpack_y(self, ypad - h, &from_north); // north halo
        Ok(sent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::run_world;

    #[test]
    fn interior_roundtrip() {
        let d = Decomp::new(8, 8, 1, 1).unwrap();
        let mut st = RankState::init(&d, 0, 2, 2, 42);
        let mut interior = st.interior();
        assert_eq!(interior.len(), NF * 2 * 8 * 8);
        interior[17] = 123.0;
        st.set_interior(&interior);
        assert_eq!(st.interior()[17], 123.0);
    }

    #[test]
    fn init_is_deterministic_and_physical() {
        let d = Decomp::new(16, 16, 1, 1).unwrap();
        let a = RankState::init(&d, 0, 2, 2, 7);
        let b = RankState::init(&d, 0, 2, 2, 7);
        assert_eq!(a.padded, b.padded);
        let interior = a.interior();
        let plane = 2 * 16 * 16;
        let th = &interior[3 * plane..4 * plane];
        assert!(th.iter().all(|&t| (250.0..350.0).contains(&t)));
        let qv = &interior[4 * plane..5 * plane];
        assert!(qv.iter().all(|&q| q >= 0.0));
    }

    #[test]
    fn patches_tile_like_single_domain() {
        // The same global field initialized as 1 rank vs 4 ranks must agree.
        let d1 = Decomp::new(8, 8, 1, 1).unwrap();
        let whole = RankState::init(&d1, 0, 1, 2, 9);
        let d4 = Decomp::new(8, 8, 2, 2).unwrap();
        for rank in 0..4 {
            let part = RankState::init(&d4, rank, 1, 2, 9);
            let (y0, x0) = d4.origin(rank);
            let pint = part.interior();
            let wint = whole.interior();
            for f in 0..NF {
                for j in 0..4 {
                    for i in 0..4 {
                        let pv = pint[(f * 4 + j) * 4 + i];
                        let wv = wint[(f * 8 + (y0 + j)) * 8 + (x0 + i)];
                        assert!(
                            (pv - wv).abs() < 1e-6,
                            "rank {rank} f{f} ({j},{i}): {pv} vs {wv}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn halo_exchange_matches_periodic_wrap() {
        // 2x2 ranks over 8x8; after exchange, each halo cell must equal the
        // periodic global field value.
        let d = Decomp::new(8, 8, 2, 2).unwrap();
        let d1 = Decomp::new(8, 8, 1, 1).unwrap();
        let whole = RankState::init(&d1, 0, 1, 2, 5);
        let wint = whole.interior(); // (NF,1,8,8)
        let states = run_world(4, 2, move |mut comm| {
            let mut st = RankState::init(&d, comm.rank(), 1, 2, 5);
            st.halo_exchange(&mut comm, &d, 100).unwrap();
            st
        });
        for (rank, st) in states.iter().enumerate() {
            let (y0, x0) = d.origin(rank);
            for f in 0..NF {
                for y in 0..st.ypad() {
                    for x in 0..st.xpad() {
                        // global coords with periodic wrap
                        let gy = (y0 + y + 8 - 2) % 8;
                        let gx = (x0 + x + 8 - 2) % 8;
                        let want = wint[(f * 8 + gy) * 8 + gx];
                        let got = st.padded[st.idx(f, 0, y, x)];
                        assert!(
                            (got - want).abs() < 1e-6,
                            "rank {rank} f{f} ({y},{x}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn halo_exchange_single_rank_self_wrap() {
        let d = Decomp::new(4, 4, 1, 1).unwrap();
        let states = run_world(1, 1, move |mut comm| {
            let mut st = RankState::init(&d, 0, 1, 2, 3);
            st.halo_exchange(&mut comm, &d, 50).unwrap();
            st
        });
        let st = &states[0];
        // west halo equals east interior columns
        for f in 0..NF {
            for j in 0..4 {
                let halo = st.padded[st.idx(f, 0, j + 2, 0)];
                let wrap = st.padded[st.idx(f, 0, j + 2, 4 - 2 + 2)];
                assert!((halo - wrap).abs() < 1e-6);
            }
        }
    }
}
