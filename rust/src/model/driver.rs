//! Forecast driver: the WRF main loop.
//!
//! Integrate → (halo exchange → PJRT step)× → write history frame → repeat,
//! with WRF-style timing accounting (`rsl.out`-like compute/I-O split) and
//! per-frame reports from the active I/O backend.  This is the L3 ↔ L2/L1
//! seam: the dynamical core runs as the AOT-compiled XLA executable, Rust
//! owns everything else.

use std::sync::Arc;

use crate::cluster::{run_world, Comm};
use crate::io::api::{FrameFields, FrameReport, HistoryBackend};
use crate::metrics::{Stopwatch, TimingLedger};
use crate::model::decomp::Decomp;
use crate::model::registry::{wrf_history_vars, VarSpec};
use crate::model::state::RankState;
use crate::adios::Variable;
use crate::runtime::ModelStep;
use crate::Result;

/// Static configuration of a forecast run.
#[derive(Debug, Clone)]
pub struct ForecastConfig {
    pub ny: usize,
    pub nx: usize,
    pub nz: usize,
    pub ranks: usize,
    pub ranks_per_node: usize,
    /// Model steps between history writes (WRF `history_interval` at our
    /// demo scale).
    pub steps_per_interval: usize,
    /// History frames to write (after the initial-condition frame).
    pub frames: usize,
    /// Also write the t=0 frame (WRF does by default).
    pub write_t0: bool,
    /// Dedicated I/O ranks appended after the compute ranks (WRF's
    /// `&namelist_quilt` semantics: quilt servers are *extra* ranks that
    /// never run the model but participate in all I/O collectives).
    pub io_ranks: usize,
    pub halo: usize,
    pub seed: u64,
    /// Simulated minutes between frames (for frame naming only).
    pub interval_minutes: usize,
}

impl ForecastConfig {
    /// WRF-style history file name for frame `i`.
    pub fn frame_name(&self, i: usize) -> String {
        let minutes = i * self.interval_minutes;
        format!(
            "wrfout_d01_2022-06-10_{:02}:{:02}:00",
            minutes / 60,
            minutes % 60
        )
    }
}

/// Rank-0 summary of a run.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub backend: &'static str,
    pub frames: Vec<FrameReport>,
    /// Measured wall-clock buckets on this host (rank-0 view).
    pub ledger: TimingLedger,
    /// Mean perceived virtual write time per frame.
    pub mean_perceived_write: f64,
    /// Mean measured compute seconds per interval.
    pub mean_compute_secs: f64,
}

/// The forecast driver.
pub struct ForecastDriver {
    pub cfg: ForecastConfig,
    pub decomp: Decomp,
    pub vars: Vec<VarSpec>,
}

impl ForecastDriver {
    pub fn new(cfg: ForecastConfig) -> Result<ForecastDriver> {
        let decomp = Decomp::auto(cfg.ny, cfg.nx, cfg.ranks)?;
        Ok(ForecastDriver {
            cfg,
            decomp,
            vars: wrf_history_vars(),
        })
    }

    /// Materialize one rank's history fields from its state.
    pub fn frame_fields(&self, st: &RankState, rank: usize) -> Result<FrameFields> {
        let (nyp, nxp) = self.decomp.patch();
        let (y0, x0) = self.decomp.origin(rank);
        let interior = st.interior();
        let mut out = Vec::with_capacity(self.vars.len());
        for spec in &self.vars {
            let data = spec.materialize(
                &interior,
                st.nf,
                st.nz,
                nyp,
                nxp,
                (y0, x0),
                self.cfg.ny,
                self.cfg.nx,
            );
            let var = if spec.is_3d {
                Variable::global(
                    spec.name,
                    &[st.nz as u64, self.cfg.ny as u64, self.cfg.nx as u64],
                    &[0, y0 as u64, x0 as u64],
                    &[st.nz as u64, nyp as u64, nxp as u64],
                )?
            } else {
                Variable::global(
                    spec.name,
                    &[self.cfg.ny as u64, self.cfg.nx as u64],
                    &[y0 as u64, x0 as u64],
                    &[nyp as u64, nxp as u64],
                )?
            };
            out.push((var, data));
        }
        Ok(out)
    }

    /// Run the forecast across an in-process world.
    ///
    /// `make_backend(rank)` builds each rank's I/O backend handle;
    /// `step` is the shared PJRT executable (patch shape must match the
    /// decomposition).  Returns the rank-0 summary.
    pub fn run<F>(&self, step: Arc<ModelStep>, make_backend: F) -> Result<RunSummary>
    where
        F: Fn(usize) -> Box<dyn HistoryBackend> + Sync,
    {
        let cfg = self.cfg.clone();
        let decomp = self.decomp;
        let (nyp, nxp) = decomp.patch();
        if step.nyp != nyp || step.nxp != nxp || step.nz != cfg.nz {
            return Err(crate::Error::model(format!(
                "executable patch {}x{}x{} does not match decomposition {}x{}x{}",
                step.nz, step.nyp, step.nxp, cfg.nz, nyp, nxp
            )));
        }
        let driver = self;
        let world = cfg.ranks + cfg.io_ranks;
        let summaries = run_world(world, cfg.ranks_per_node, |mut comm: Comm| -> Result<RunSummary> {
            let rank = comm.rank();
            let mut ledger = TimingLedger::default();
            let mut backend = make_backend(rank);

            if rank >= cfg.ranks {
                // Dedicated I/O rank: no model state; join every I/O
                // collective with an empty contribution.
                let frames = cfg.frames + usize::from(cfg.write_t0);
                for frame_idx in 0..frames {
                    let name = cfg.frame_name(frame_idx);
                    backend.write_frame(&mut comm, frame_idx, &name, Vec::new())?;
                }
                backend.finish(&mut comm)?;
                return Ok(RunSummary::default());
            }

            let sw_init = Stopwatch::start();
            let mut st = RankState::init(&decomp, rank, cfg.nz, cfg.halo, cfg.seed);
            ledger.add("init", sw_init.secs());

            let mut frame_idx = 0usize;
            if cfg.write_t0 {
                let sw = Stopwatch::start();
                let fields = driver.frame_fields(&st, rank)?;
                backend.write_frame(&mut comm, frame_idx, &cfg.frame_name(0), fields)?;
                ledger.add("io", sw.secs());
                frame_idx += 1;
            }

            let mut tag = 1_000u64;
            for interval in 0..cfg.frames {
                let sw_c = Stopwatch::start();
                for _ in 0..cfg.steps_per_interval {
                    st.halo_exchange(&mut comm, &decomp, tag)?;
                    tag += 4;
                    let interior = step.step(&st.padded)?;
                    st.set_interior(&interior);
                }
                ledger.add("compute", sw_c.secs());

                let sw_io = Stopwatch::start();
                let fields = driver.frame_fields(&st, rank)?;
                backend.write_frame(&mut comm, frame_idx, &cfg.frame_name(interval + 1), fields)?;
                ledger.add("io", sw_io.secs());
                frame_idx += 1;
            }

            let name = backend.name();
            let frames = backend.finish(&mut comm)?;
            if rank == 0 {
                let mean_perceived = if frames.is_empty() {
                    0.0
                } else {
                    frames.iter().map(|f| f.perceived()).sum::<f64>() / frames.len() as f64
                };
                Ok(RunSummary {
                    backend: name,
                    mean_perceived_write: mean_perceived,
                    mean_compute_secs: ledger.get("compute") / cfg.frames.max(1) as f64,
                    frames,
                    ledger,
                })
            } else {
                Ok(RunSummary::default())
            }
        });
        summaries.into_iter().next().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::adios2::Adios2Backend;
    use crate::adios::Adios;
    use crate::runtime::{Manifest, XlaRuntime};
    use crate::sim::{CostModel, HardwareSpec};

    fn artifacts() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn frame_name_format() {
        let cfg = ForecastConfig {
            ny: 8,
            nx: 8,
            nz: 1,
            ranks: 1,
            ranks_per_node: 1,
            steps_per_interval: 1,
            frames: 4,
            write_t0: true,
            io_ranks: 0,
            halo: 2,
            seed: 0,
            interval_minutes: 30,
        };
        assert_eq!(cfg.frame_name(0), "wrfout_d01_2022-06-10_00:00:00");
        assert_eq!(cfg.frame_name(3), "wrfout_d01_2022-06-10_01:30:00");
    }

    #[test]
    fn forecast_end_to_end_small() {
        if !artifacts().join("manifest.txt").exists() {
            eprintln!("SKIP forecast test: AOT artifacts not built");
            return;
        }
        let rt = match XlaRuntime::new() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("SKIP forecast test: XLA runtime unavailable: {e}");
                return;
            }
        };
        let man = Manifest::load(artifacts()).unwrap();
        let step = Arc::new(crate::runtime::ModelStep::load(&rt, &man, 96, 96).unwrap());
        let cfg = ForecastConfig {
            ny: 192,
            nx: 192,
            nz: 4,
            ranks: 4,
            ranks_per_node: 2,
            steps_per_interval: 2,
            frames: 2,
            write_t0: true,
            io_ranks: 0,
            halo: 2,
            seed: 11,
            interval_minutes: 30,
        };
        let driver = ForecastDriver::new(cfg).unwrap();
        let dir = std::env::temp_dir().join(format!("stormio_drv_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d2 = dir.clone();
        let doc = r#"<adios-config><io name="hist">
           <engine type="BP4"/>
           <operator type="blosc"><parameter key="codec" value="lz4"/></operator>
        </io></adios-config>"#;
        let summary = driver
            .run(step, |_rank| {
                Box::new(
                    Adios2Backend::new(
                        Adios::from_xml(doc).unwrap(),
                        "hist",
                        d2.join("pfs"),
                        d2.join("bb"),
                        CostModel::new(HardwareSpec::paper_testbed(2)),
                    )
                    .unwrap(),
                )
            })
            .unwrap();
        assert_eq!(summary.frames.len(), 3); // t0 + 2 intervals
        assert!(summary.mean_perceived_write > 0.0);
        assert!(summary.ledger.get("compute") > 0.0);
        // Verify a history frame reconstitutes and is physical.
        let rd = crate::adios::bp::reader::BpReader::open(
            dir.join("pfs")
                .join(format!("{}.bp", driver.cfg.frame_name(2))),
        )
        .unwrap();
        let (shape, th) = rd.read_var_global(0, "T").unwrap();
        assert_eq!(shape, vec![4, 192, 192]);
        // T = theta - 300 stays in a physical band.
        assert!(th.iter().all(|&t| t > -60.0 && t < 60.0));
        // Real WRF-scale variable count flowed through the stack.
        assert!(rd.var_names(0).unwrap().len() >= 60);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
