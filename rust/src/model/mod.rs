//! The WRF-analog forecast model: domain decomposition, per-rank state,
//! the history-variable registry, and the forecast driver that executes
//! the AOT-compiled JAX/Pallas step through PJRT and emits history frames
//! through a pluggable I/O backend.

pub mod decomp;
pub mod driver;
pub mod registry;
pub mod state;

pub use decomp::Decomp;
pub use driver::{ForecastConfig, ForecastDriver, RunSummary};
pub use registry::{wrf_history_vars, VarSpec};
pub use state::RankState;
