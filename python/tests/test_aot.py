"""AOT lowering tests: HLO text artifacts are well-formed and parseable."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import HALO


def test_lower_rank_step_produces_hlo_text():
    text = aot.lower_rank_step(2, 8, 8)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f32 patch input of the right shape appears as a parameter.
    assert f"f32[{model.NF},2,{8 + 2 * HALO},{8 + 2 * HALO}]" in text


def test_lower_analysis_produces_hlo_text():
    text = aot.lower_analysis(2, 32, 32)
    assert "HloModule" in text
    assert "f32[2,32,32]" in text


def test_lowered_module_executes_and_matches_eager():
    """Round-trip: the lowered computation equals eager rank_step."""
    nz, nyp, nxp = 2, 8, 8
    spec_shape = (model.NF, nz, nyp + 2 * HALO, nxp + 2 * HALO)
    rng = np.random.default_rng(0)
    state = jnp.asarray(
        1.0 + 0.1 * rng.standard_normal(spec_shape), jnp.float32
    )
    lowered = jax.jit(lambda s: (model.rank_step(s),)).lower(
        jax.ShapeDtypeStruct(spec_shape, jnp.float32)
    )
    compiled = lowered.compile()
    out = compiled(state)[0]
    np.testing.assert_allclose(out, model.rank_step(state), rtol=1e-5, atol=1e-6)


def test_manifest_patch_table_consistent():
    tags = {t for t, _, _, _ in aot.PATCHES}
    assert len(tags) == len(aot.PATCHES), "duplicate patch tags"
    for tag, nz, nyp, nxp in aot.PATCHES:
        assert tag == f"p{nyp}x{nxp}"
        assert nyp % 4 == 0 and nxp % 4 == 0  # analysis downsample divides


def test_artifacts_on_disk_if_built():
    """If `make artifacts` has run, the manifest must index real files."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built yet")
    with open(manifest) as fh:
        lines = [l.split() for l in fh if l.strip() and not l.startswith("#")]
    files = [
        kv.split("=", 1)[1]
        for parts in lines
        for kv in parts
        if kv.startswith("file=")
    ]
    assert files, "manifest lists no artifacts"
    for f in files:
        p = os.path.join(art, f)
        assert os.path.exists(p), f
        with open(p) as fh:
            head = fh.read(200)
        assert "HloModule" in head
