"""L2 model tests: rank_step semantics, stability, physics sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import HALO


def pad_periodic(interior):
    """Periodic halo fill of a (NF, NZ, NY, NX) global state."""
    return jnp.pad(
        interior, ((0, 0), (0, 0), (HALO, HALO), (HALO, HALO)), mode="wrap"
    )


def test_rank_step_shapes_dtype():
    nz, ny, nx = 2, 16, 20
    state = model.initial_global_state(nz, ny, nx, seed=1)
    out = model.rank_step(pad_periodic(state))
    assert out.shape == (model.NF, nz, ny, nx)
    assert out.dtype == jnp.float32


def test_rank_step_matches_ref_twin():
    nz, ny, nx = 2, 12, 12
    state = pad_periodic(model.initial_global_state(nz, ny, nx, seed=2))
    np.testing.assert_allclose(
        model.rank_step(state),
        model.rank_step_ref(state),
        rtol=1e-4,
        atol=1e-5,
    )


def test_stability_200_steps_no_nan():
    """The demo configuration must integrate stably (the end-to-end run)."""
    nz, ny, nx = 2, 32, 32
    s = model.initial_global_state(nz, ny, nx, seed=3)
    step = jax.jit(lambda x: model.rank_step(pad_periodic(x)))
    for _ in range(200):
        s = step(s)
    assert bool(jnp.isfinite(s).all())
    # Flow should still be moving, not diffused to rest.
    assert float(jnp.abs(s[1]).max()) > 1e-3


def test_mass_conservation_periodic():
    """With periodic halos, total mass sum(h) drifts only at fp roundoff."""
    nz, ny, nx = 1, 24, 24
    s = model.initial_global_state(nz, ny, nx, seed=4)
    m0 = float(s[0].sum())
    step = jax.jit(lambda x: model.rank_step(pad_periodic(x)))
    for _ in range(50):
        s = step(s)
    m1 = float(s[0].sum())
    assert abs(m1 - m0) / abs(m0) < 1e-4


def test_moisture_nonnegative():
    nz, ny, nx = 2, 24, 24
    s = model.initial_global_state(nz, ny, nx, seed=5)
    step = jax.jit(lambda x: model.rank_step(pad_periodic(x)))
    for _ in range(50):
        s = step(s)
    assert float(s[4].min()) >= 0.0


def test_initial_state_realistic_ranges():
    s = model.initial_global_state(4, 48, 48, seed=6)
    h, u, v, th, qv = (np.asarray(s[i]) for i in range(model.NF))
    assert h.min() > 0.5 and h.max() < 3.0
    assert 250.0 < th.min() and th.max() < 340.0
    assert qv.min() >= 0.0
    # Fields must be smooth (compressible): neighbour deltas small vs range.
    d = np.abs(np.diff(th[0], axis=-1)).mean()
    assert d < 0.1 * (th[0].max() - th[0].min())


def test_analysis_fn_outputs():
    nz, ny, nx = 4, 64, 64
    th = model.initial_global_state(nz, ny, nx, seed=7)[3]
    ds, lmean, lmin, lmax, hist = model.analysis_fn(th)
    assert ds.shape == (ny // 4, nx // 4)
    assert lmean.shape == (nz,)
    assert int(hist.sum()) == ny * nx
    assert bool((lmin <= lmean).all()) and bool((lmean <= lmax).all())


def test_analysis_fn_constant_field():
    th = jnp.full((2, 16, 16), 300.0, jnp.float32)
    ds, lmean, lmin, lmax, hist = model.analysis_fn(th)
    np.testing.assert_allclose(ds, 300.0)
    assert int(hist.sum()) == 16 * 16
