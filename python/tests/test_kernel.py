"""L1 correctness: Pallas stencil kernel vs the pure-jnp oracle.

The kernel-vs-ref allclose is the core correctness signal for the whole
compile path — if these pass, the HLO the Rust runtime executes computes the
same update the oracle defines.  Hypothesis sweeps patch shapes, level
counts and flow regimes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import HALO, advect_tracer_ref, sw_step_ref
from compile.kernels.sw_stencil import sw_step_pallas, vmem_bytes_estimate

P = dict(dt=0.02, dx=1.0, dy=1.0, g=10.0, f=0.5, nu=0.05)


def random_patch(nz, nyp, nxp, seed=0, u0=0.3, hamp=0.2):
    rng = np.random.default_rng(seed)
    shape = (nz, nyp + 2 * HALO, nxp + 2 * HALO)
    h = 1.0 + hamp * rng.standard_normal(shape)
    u = u0 + 0.1 * rng.standard_normal(shape)
    v = 0.1 * rng.standard_normal(shape)
    return (
        jnp.asarray(h, jnp.float32),
        jnp.asarray(u, jnp.float32),
        jnp.asarray(v, jnp.float32),
    )


def test_kernel_matches_ref_basic():
    h, u, v = random_patch(4, 16, 24, seed=1)
    got = sw_step_pallas(h, u, v, **P)
    want = sw_step_ref(h, u, v, **P)
    for g_, w_, name in zip(got, want, "huv"):
        np.testing.assert_allclose(g_, w_, rtol=1e-5, atol=1e-6, err_msg=name)


def test_kernel_output_shapes():
    h, u, v = random_patch(3, 10, 14)
    out = sw_step_pallas(h, u, v, **P)
    for o in out:
        assert o.shape == (3, 10, 14)
        assert o.dtype == jnp.float32


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    nz=st.integers(1, 6),
    nyp=st.integers(4, 40),
    nxp=st.integers(4, 40),
    seed=st.integers(0, 2**31 - 1),
    u0=st.floats(-1.0, 1.0),
    hamp=st.floats(0.0, 0.4),
)
def test_kernel_matches_ref_sweep(nz, nyp, nxp, seed, u0, hamp):
    """Kernel == oracle across shapes and flow regimes."""
    h, u, v = random_patch(nz, nyp, nxp, seed=seed, u0=u0, hamp=hamp)
    got = sw_step_pallas(h, u, v, **P)
    want = sw_step_ref(h, u, v, **P)
    for g_, w_ in zip(got, want):
        np.testing.assert_allclose(g_, w_, rtol=1e-4, atol=1e-5)


def test_kernel_under_jit_and_grad_free():
    """The kernel must lower inside jit (the AOT path) bit-identically."""
    h, u, v = random_patch(2, 12, 12, seed=3)
    eager = sw_step_pallas(h, u, v, **P)
    jitted = jax.jit(lambda a, b, c: sw_step_pallas(a, b, c, **P))(h, u, v)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(e, j, rtol=1e-6, atol=1e-7)


def test_rest_state_is_fixed_point():
    """h=const, u=v=0 must be an exact steady state of the scheme."""
    nz, nyp, nxp = 2, 8, 8
    shape = (nz, nyp + 2 * HALO, nxp + 2 * HALO)
    h = jnp.full(shape, 1.0, jnp.float32)
    z = jnp.zeros(shape, jnp.float32)
    hn, un, vn = sw_step_pallas(h, z, z, **P)
    np.testing.assert_allclose(hn, 1.0, atol=1e-7)
    np.testing.assert_allclose(un, 0.0, atol=1e-7)
    np.testing.assert_allclose(vn, 0.0, atol=1e-7)


def test_geostrophic_symmetry():
    """Mirroring the domain in x flips u and dh/dx consistently.

    A discrete symmetry check: step(mirror(state)) == mirror(step(state))
    where mirror reverses x and negates u.
    """
    h, u, v = random_patch(2, 12, 16, seed=7)
    hn, un, vn = sw_step_ref(h, u, v, **P)

    hm = h[:, :, ::-1]
    um = -u[:, :, ::-1]
    vm = v[:, :, ::-1]
    # x-mirror breaks Coriolis sign pairing unless f -> -f.
    Pm = dict(P, f=-P["f"])
    hn2, un2, vn2 = sw_step_ref(hm, um, vm, **Pm)
    np.testing.assert_allclose(hn2, hn[:, :, ::-1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(un2, -un[:, :, ::-1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(vn2, vn[:, :, ::-1], rtol=1e-5, atol=1e-6)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    nyp=st.integers(4, 32),
    nxp=st.integers(4, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_tracer_upwind_bounded(nyp, nxp, seed):
    """Upwind advection without diffusion can't create new extrema."""
    rng = np.random.default_rng(seed)
    nz = 2
    shape = (nz, nyp + 2 * HALO, nxp + 2 * HALO)
    c = jnp.asarray(rng.uniform(0.0, 1.0, shape), jnp.float32)
    # CFL-safe velocities.
    u = jnp.asarray(rng.uniform(-1.0, 1.0, (nz, nyp, nxp)), jnp.float32)
    v = jnp.asarray(rng.uniform(-1.0, 1.0, (nz, nyp, nxp)), jnp.float32)
    cn = advect_tracer_ref(c, u, v, dt=0.02, dx=1.0, dy=1.0, kappa=0.0)
    assert float(cn.min()) >= float(c.min()) - 1e-5
    assert float(cn.max()) <= float(c.max()) + 1e-5


def test_vmem_estimate_within_budget():
    """Compiled block shapes must fit the ~16 MiB TPU VMEM budget."""
    for nyp, nxp in [(96, 96), (48, 48), (24, 24)]:
        est = vmem_bytes_estimate(1, nyp + 2 * HALO, nxp + 2 * HALO, nyp, nxp)
        assert est < 16 * 1024 * 1024, (nyp, nxp, est)
