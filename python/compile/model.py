"""L2 JAX model: the WRF-analog per-rank forecast step.

WRF's ARW dynamical core integrates the compressible non-hydrostatic
equations with dozens of prognostic variables; its I/O layer (the subject of
the reproduced paper) sees those variables as a long list of named
distributed 2-D/3-D arrays.  This module is the compute stand-in (DESIGN.md
§Substitutions): a stack of ``NZ`` nonlinear shallow-water levels plus two
advected tracers (potential temperature θ and moisture q_v), which produces
realistically smooth, evolving multi-variable fields for the I/O stack to
write.

The hot-spot (the shallow-water stencil update) is the L1 Pallas kernel in
``kernels/sw_stencil.py``; the tracer advection and Rayleigh relaxation wrap
around it in plain jnp so XLA fuses them into the same module.

``rank_step`` is the function AOT-lowered (per patch shape) by ``aot.py``
and executed from the Rust coordinator (``rust/src/runtime``) — one call
advances one rank's padded patch by one model time step.  Halo exchange
happens in Rust between calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.ref import HALO, advect_tracer_ref
from .kernels.sw_stencil import sw_step_pallas

# Scheme constants, baked into the HLO at lowering time.  Values give a
# stable, visibly evolving flow for dx = 1 grid units and dt = 0.02:
# gravity-wave CFL  c*dt/dx = sqrt(g*h0)*dt/dx ≈ sqrt(10*1)*0.02 ≈ 0.063.
DEFAULTS = dict(
    dt=0.02,   # time step
    dx=1.0,    # grid spacing (x)
    dy=1.0,    # grid spacing (y)
    g=10.0,    # gravity
    f=0.5,     # Coriolis parameter
    nu=0.05,   # momentum diffusion
    kappa=0.05,  # tracer diffusion
)

#: Prognostic patch fields, in the order they appear in the stacked
#: ``(NF, NZ, NYP+2H, NXP+2H)`` state array exchanged with Rust.
FIELDS = ("HGT_FLD", "U", "V", "THETA", "QVAPOR")
NF = len(FIELDS)


def rank_step(state, **overrides):
    """Advance one rank's padded patch state by one model step.

    Args:
      state: ``(NF, NZ, NYP+2H, NXP+2H)`` float32 stacked patch
        (order per :data:`FIELDS`) with halos already filled.

    Returns:
      ``(NF, NZ, NYP, NXP)`` float32 updated interior.  The coordinator
      re-pads and refills halos before the next call.
    """
    p = dict(DEFAULTS, **overrides)
    h, u, v, th, qv = (state[i] for i in range(NF))

    h_n, u_n, v_n = sw_step_pallas(
        h, u, v, dt=p["dt"], dx=p["dx"], dy=p["dy"], g=p["g"], f=p["f"], nu=p["nu"]
    )
    adv = functools.partial(
        advect_tracer_ref, dt=p["dt"], dx=p["dx"], dy=p["dy"], kappa=p["kappa"]
    )
    th_n = adv(th, u_n, v_n)
    qv_n = adv(qv, u_n, v_n)
    # Moisture is non-negative; clamp like WRF's positive-definite advection.
    qv_n = jnp.maximum(qv_n, 0.0)
    return jnp.stack([h_n, u_n, v_n, th_n, qv_n])


def rank_step_ref(state, **overrides):
    """Oracle twin of :func:`rank_step` using the pure-jnp stencil."""
    from .kernels.ref import sw_step_ref

    p = dict(DEFAULTS, **overrides)
    h, u, v, th, qv = (state[i] for i in range(NF))
    h_n, u_n, v_n = sw_step_ref(
        h, u, v, dt=p["dt"], dx=p["dx"], dy=p["dy"], g=p["g"], f=p["f"], nu=p["nu"]
    )
    adv = functools.partial(
        advect_tracer_ref, dt=p["dt"], dx=p["dx"], dy=p["dy"], kappa=p["kappa"]
    )
    th_n = adv(th, u_n, v_n)
    qv_n = jnp.maximum(adv(qv, u_n, v_n), 0.0)
    return jnp.stack([h_n, u_n, v_n, th_n, qv_n])


def initial_global_state(nz, ny, nx, seed=0):
    """Synthesize a CONUS-proxy initial condition on the *global* grid.

    A zonal jet perturbed by a few gaussian height anomalies (the "storms"),
    θ with a meridional gradient + anomalies, q_v moist blobs — smooth
    fields with WRF-like spatial correlation so downstream compression
    ratios are realistic.

    Returns:
      ``(NF, NZ, NY, NX)`` float32 (unpadded global state).
    """
    key = jax.random.PRNGKey(seed)
    yy, xx = jnp.meshgrid(
        jnp.linspace(0.0, 1.0, ny), jnp.linspace(0.0, 1.0, nx), indexing="ij"
    )

    def bumps(k, n, amp, width):
        ks = jax.random.split(k, 3)
        cx = jax.random.uniform(ks[0], (n,))
        cy = jax.random.uniform(ks[1], (n,))
        a = amp * jax.random.uniform(ks[2], (n,), minval=0.5, maxval=1.0)
        field = jnp.zeros((ny, nx))
        for i in range(n):
            r2 = (xx - cx[i]) ** 2 + (yy - cy[i]) ** 2
            field = field + a[i] * jnp.exp(-r2 / (2.0 * width**2))
        return field

    levels = []
    keys = jax.random.split(key, nz)
    for z in range(nz):
        kz = jax.random.split(keys[z], 4)
        lev_scale = 1.0 - 0.08 * z  # weak vertical structure
        h = 1.0 + 0.1 * bumps(kz[0], 4, 1.0, 0.08) * lev_scale
        u = 0.5 * jnp.sin(2.0 * jnp.pi * yy) * lev_scale + 0.05 * bumps(
            kz[1], 3, 1.0, 0.1
        )
        v = 0.05 * bumps(kz[2], 3, 1.0, 0.1)
        th = 280.0 + 30.0 * yy + 5.0 * bumps(kz[3], 4, 1.0, 0.06) + 2.0 * z
        qv = jnp.maximum(0.0, 0.01 * bumps(kz[3], 5, 1.0, 0.05))
        levels.append(jnp.stack([h, u, v, th, qv]))
    # levels: list of (NF, NY, NX) -> (NF, NZ, NY, NX)
    return jnp.stack(levels, axis=1).astype(jnp.float32)


def analysis_fn(theta):
    """In-situ analysis computation (consumer side of the SST pipeline).

    Mirrors the paper's forecast post-processing: extract a temperature
    slice over the domain and reduce it for plotting.  Lowered to
    ``artifacts/analysis.hlo.txt`` and executed by the Rust in-situ consumer.

    Args:
      theta: ``(NZ, NY, NX)`` potential-temperature field.

    Returns:
      (slice_ds, level_mean, level_min, level_max, hist) where slice_ds is
      the surface level downsampled 4× in each direction for rendering and
      hist is a 32-bin histogram of the surface level.
    """
    surf = theta[0]
    ny, nx = surf.shape
    ds = surf.reshape(ny // 4, 4, nx // 4, 4).mean(axis=(1, 3))
    lmean = theta.mean(axis=(1, 2))
    lmin = theta.min(axis=(1, 2))
    lmax = theta.max(axis=(1, 2))
    lo, hi = surf.min(), surf.max()
    # Guard the degenerate constant-field case (hi == lo).
    span = jnp.maximum(hi - lo, 1e-6)
    idx = jnp.clip(((surf - lo) / span * 32.0).astype(jnp.int32), 0, 31)
    hist = jnp.zeros((32,), jnp.int32).at[idx.reshape(-1)].add(1)
    return ds, lmean, lmin, lmax, hist
