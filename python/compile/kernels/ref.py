"""Pure-jnp oracle for the L1 Pallas shallow-water stencil kernel.

This module is the ground truth for ``kernels/sw_stencil.py``: the same
forward-backward Arakawa-A finite-difference update, written as plain
``jax.numpy`` slicing with no Pallas machinery.  pytest (including the
hypothesis sweeps in ``python/tests/test_kernel.py``) asserts the Pallas
kernel matches this reference to float32 tolerance across shapes.

Grid conventions
----------------
All fields are ``(NZ, NYP + 2*HALO, NXP + 2*HALO)`` float32 patches: a stack
of ``NZ`` independent shallow-water levels (the WRF-proxy "atmosphere"),
padded with a ``HALO``-deep ring filled by the coordinator from neighbouring
ranks before every step.  The update writes only the interior
``(NZ, NYP, NXP)`` region.

The scheme is the classic forward-backward shallow-water step:

  1. continuity first:   h' = h - dt * div(h u, h v)        (needs halo 1)
  2. momentum backward:  u' = u + dt * (f v - g dh'/dx - adv(u)) + diff
                         v' = v + dt * (-f u - g dh'/dy - adv(v)) + diff

Step 2 needs ``h'`` one ring beyond the interior, hence ``HALO = 2``.
"""

from __future__ import annotations

import jax.numpy as jnp

HALO = 2


def _ddx(a, dx):
    """Centered x-derivative, consuming one halo ring in x."""
    return (a[:, :, 2:] - a[:, :, :-2]) / (2.0 * dx)


def _ddy(a, dy):
    """Centered y-derivative, consuming one halo ring in y."""
    return (a[:, 2:, :] - a[:, :-2, :]) / (2.0 * dy)


def _lap(a, dx, dy):
    """5-point Laplacian on the interior of a (..., Y, X) array."""
    return (a[:, 1:-1, 2:] - 2.0 * a[:, 1:-1, 1:-1] + a[:, 1:-1, :-2]) / (
        dx * dx
    ) + (a[:, 2:, 1:-1] - 2.0 * a[:, 1:-1, 1:-1] + a[:, :-2, 1:-1]) / (dy * dy)


def sw_step_ref(h, u, v, *, dt, dx, dy, g, f, nu):
    """One forward-backward shallow-water step on a 2-halo padded patch.

    Args:
      h, u, v: ``(NZ, NYP+4, NXP+4)`` float32 padded fields.
      dt, dx, dy, g, f, nu: scheme constants (python floats, baked at trace
        time exactly as the Pallas kernel bakes them).

    Returns:
      ``(h_new, u_new, v_new)`` interior patches of shape ``(NZ, NYP, NXP)``.
    """
    # ---- continuity (forward): h' on interior + 1 ring -------------------
    # Strip one ring off the 2-halo patch so every centered difference below
    # lands on the interior+1 ring.
    hs = h[:, 1:-1, 1:-1]
    us = u[:, 1:-1, 1:-1]
    vs = v[:, 1:-1, 1:-1]
    hu = h * u
    hv = h * v
    div = _ddx(hu[:, 1:-1, :], dx) + _ddy(hv[:, :, 1:-1], dy)
    h_prime = hs - dt * div  # shape (NZ, NYP+2, NXP+2): interior + 1 ring

    # ---- momentum (backward, uses h') ------------------------------------
    ui = u[:, HALO:-HALO, HALO:-HALO]
    vi = v[:, HALO:-HALO, HALO:-HALO]

    dhdx = _ddx(h_prime[:, 1:-1, :], dx)
    dhdy = _ddy(h_prime[:, :, 1:-1], dy)

    adv_u = ui * _ddx(us[:, 1:-1, :], dx) + vi * _ddy(us[:, :, 1:-1], dy)
    adv_v = ui * _ddx(vs[:, 1:-1, :], dx) + vi * _ddy(vs[:, :, 1:-1], dy)

    u_new = ui + dt * (f * vi - g * dhdx - adv_u + nu * _lap(us, dx, dy))
    v_new = vi + dt * (-f * ui - g * dhdy - adv_v + nu * _lap(vs, dx, dy))
    h_new = h_prime[:, 1:-1, 1:-1]
    return h_new, u_new, v_new


def advect_tracer_ref(c, u_new, v_new, *, dt, dx, dy, kappa):
    """First-order upwind advection + diffusion of a tracer patch.

    Args:
      c: ``(NZ, NYP+4, NXP+4)`` padded tracer.
      u_new, v_new: interior ``(NZ, NYP, NXP)`` advecting velocities.
      dt, dx, dy, kappa: constants.

    Returns:
      Interior ``(NZ, NYP, NXP)`` updated tracer.
    """
    ci = c[:, HALO:-HALO, HALO:-HALO]
    cxp = c[:, HALO:-HALO, HALO + 1 : -(HALO - 1)]
    cxm = c[:, HALO:-HALO, HALO - 1 : -(HALO + 1)]
    cyp = c[:, HALO + 1 : -(HALO - 1), HALO:-HALO]
    cym = c[:, HALO - 1 : -(HALO + 1), HALO:-HALO]

    flux_x = jnp.where(u_new > 0.0, u_new * (ci - cxm), u_new * (cxp - ci)) / dx
    flux_y = jnp.where(v_new > 0.0, v_new * (ci - cym), v_new * (cyp - ci)) / dy
    lap = (cxp - 2.0 * ci + cxm) / (dx * dx) + (cyp - 2.0 * ci + cym) / (dy * dy)
    return ci - dt * (flux_x + flux_y) + dt * kappa * lap
