"""L1 Pallas kernel: forward-backward shallow-water stencil update.

This is the WRF-analog's compute hot-spot, written as a Pallas kernel so it
lowers into the same HLO module as the surrounding L2 jax model
(``compile/model.py``) and runs from the Rust PJRT runtime with no Python on
the request path.

TPU mapping (see DESIGN.md §Hardware-Adaptation)
------------------------------------------------
* The Pallas ``grid`` iterates over the NZ vertical levels; each program
  instance owns one full ``(NYP+2H, NXP+2H)`` level plane.  For the patch
  sizes this repo compiles (≤ 128×128 + halo, f32) a full plane is ≤ 70 KiB,
  so three input planes + three output planes sit comfortably in the ~16 MiB
  VMEM budget of a TPU core — the BlockSpec *is* the HBM↔VMEM schedule that
  a CUDA port would express with threadblocks + shared-memory staging.
* A stencil has no matmul, so the MXU is idle by construction; the update is
  pure VPU (8×128 vector lanes) work.  Everything below is written as whole-
  plane vectorized ops — no scalar loops — so the VPU lanes stay full.
* ``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
  Mosaic custom-calls.  Interpret mode lowers the kernel to plain HLO ops,
  which is exactly what the Rust runtime loads.

Correctness is pinned to the pure-jnp oracle in ``kernels/ref.py`` by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes and flow regimes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HALO


def _sw_kernel(h_ref, u_ref, v_ref, oh_ref, ou_ref, ov_ref, *, dt, dx, dy, g, f, nu):
    """Kernel body for one vertical level.

    Refs are ``(1, NYP+2H, NXP+2H)`` blocks (inputs) and ``(1, NYP, NXP)``
    blocks (outputs).  The math mirrors ``ref.sw_step_ref`` exactly; keeping
    the two in lockstep is enforced by the test suite, so any scheme change
    must land in both files.
    """
    h = h_ref[...]
    u = u_ref[...]
    v = v_ref[...]

    inv2dx = 1.0 / (2.0 * dx)
    inv2dy = 1.0 / (2.0 * dy)

    # ---- continuity (forward): h' on interior + 1 ring -------------------
    hu = h * u
    hv = h * v
    div = (hu[:, 1:-1, 2:] - hu[:, 1:-1, :-2]) * inv2dx + (
        hv[:, 2:, 1:-1] - hv[:, :-2, 1:-1]
    ) * inv2dy
    h_prime = h[:, 1:-1, 1:-1] - dt * div  # (1, NYP+2, NXP+2)

    # ---- momentum (backward) ---------------------------------------------
    us = u[:, 1:-1, 1:-1]
    vs = v[:, 1:-1, 1:-1]
    ui = u[:, HALO:-HALO, HALO:-HALO]
    vi = v[:, HALO:-HALO, HALO:-HALO]

    dhdx = (h_prime[:, 1:-1, 2:] - h_prime[:, 1:-1, :-2]) * inv2dx
    dhdy = (h_prime[:, 2:, 1:-1] - h_prime[:, :-2, 1:-1]) * inv2dy

    dudx = (us[:, 1:-1, 2:] - us[:, 1:-1, :-2]) * inv2dx
    dudy = (us[:, 2:, 1:-1] - us[:, :-2, 1:-1]) * inv2dy
    dvdx = (vs[:, 1:-1, 2:] - vs[:, 1:-1, :-2]) * inv2dx
    dvdy = (vs[:, 2:, 1:-1] - vs[:, :-2, 1:-1]) * inv2dy

    lap_u = (us[:, 1:-1, 2:] - 2.0 * us[:, 1:-1, 1:-1] + us[:, 1:-1, :-2]) / (
        dx * dx
    ) + (us[:, 2:, 1:-1] - 2.0 * us[:, 1:-1, 1:-1] + us[:, :-2, 1:-1]) / (dy * dy)
    lap_v = (vs[:, 1:-1, 2:] - 2.0 * vs[:, 1:-1, 1:-1] + vs[:, 1:-1, :-2]) / (
        dx * dx
    ) + (vs[:, 2:, 1:-1] - 2.0 * vs[:, 1:-1, 1:-1] + vs[:, :-2, 1:-1]) / (dy * dy)

    adv_u = ui * dudx + vi * dudy
    adv_v = ui * dvdx + vi * dvdy

    ou_ref[...] = ui + dt * (f * vi - g * dhdx - adv_u + nu * lap_u)
    ov_ref[...] = vi + dt * (-f * ui - g * dhdy - adv_v + nu * lap_v)
    oh_ref[...] = h_prime[:, 1:-1, 1:-1]


def sw_step_pallas(h, u, v, *, dt, dx, dy, g, f, nu, interpret=True):
    """One shallow-water step over all NZ levels via a Pallas grid.

    Args:
      h, u, v: ``(NZ, NYP+2H, NXP+2H)`` float32 padded patches.
      interpret: keep True for CPU PJRT (see module docstring).

    Returns:
      ``(h_new, u_new, v_new)`` interior ``(NZ, NYP, NXP)`` arrays.
    """
    nz, ypad, xpad = h.shape
    nyp, nxp = ypad - 2 * HALO, xpad - 2 * HALO
    kern = functools.partial(_sw_kernel, dt=dt, dx=dx, dy=dy, g=g, f=f, nu=nu)

    in_spec = pl.BlockSpec((1, ypad, xpad), lambda z: (z, 0, 0))
    out_spec = pl.BlockSpec((1, nyp, nxp), lambda z: (z, 0, 0))
    out_shape = jax.ShapeDtypeStruct((nz, nyp, nxp), h.dtype)

    return pl.pallas_call(
        kern,
        grid=(nz,),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=interpret,
    )(h, u, v)


def vmem_bytes_estimate(nz_block, ypad, xpad, nyp, nxp, itemsize=4):
    """Static VMEM footprint estimate for one program instance.

    Used by DESIGN/EXPERIMENTS §Perf to argue the block shape respects the
    ~16 MiB/core VMEM budget: 3 input planes + 3 output planes + the ~6
    intermediate interior+ring temporaries the scheduler must hold live.
    """
    inputs = 3 * nz_block * ypad * xpad * itemsize
    outputs = 3 * nz_block * nyp * nxp * itemsize
    temps = 6 * nz_block * (nyp + 2) * (nxp + 2) * itemsize
    return inputs + outputs + temps
