"""AOT compile path: lower the L2/L1 model to HLO text artifacts.

Runs once at build time (``make artifacts``); Python never runs on the Rust
request path.  For each compiled patch decomposition this emits

  artifacts/model_p{NYP}x{NXP}.hlo.txt   — one rank_step per patch shape
  artifacts/analysis_{NY}x{NX}.hlo.txt   — in-situ consumer computation
  artifacts/manifest.txt                 — shapes/constants for the Rust side

Interchange format is HLO **text**, not ``HloModuleProto.serialize()``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import HALO

#: Patch decompositions compiled by default.  Each entry is
#: (tag, nz, nyp, nxp) — the Rust coordinator picks the artifact whose patch
#: shape matches the decomposition requested in namelist.input.
#: 96x96 serves the 2x2-rank demo global grid (192x192); 48x48 serves both
#: the 4x4-rank demo and the CONUS-proxy I/O-bench grids.
PATCHES = [
    ("p96x96", 4, 96, 96),
    ("p48x48", 4, 48, 48),
    ("p24x24", 4, 24, 24),
]

#: Analysis (consumer-side) global grids to compile.
ANALYSIS_GRIDS = [(4, 192, 192)]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_rank_step(nz: int, nyp: int, nxp: int) -> str:
    spec = jax.ShapeDtypeStruct(
        (model.NF, nz, nyp + 2 * HALO, nxp + 2 * HALO), jnp.float32
    )
    # donate_argnums lets XLA reuse the (large) state buffer for the output.
    lowered = jax.jit(lambda s: (model.rank_step(s),), donate_argnums=0).lower(spec)
    return to_hlo_text(lowered)


def lower_analysis(nz: int, ny: int, nx: int) -> str:
    spec = jax.ShapeDtypeStruct((nz, ny, nx), jnp.float32)
    lowered = jax.jit(lambda t: tuple(model.analysis_fn(t))).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="legacy single-output path (ignored)")
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    manifest = [
        "# stormio artifact manifest — parsed by rust/src/runtime/manifest.rs",
        f"halo {HALO}",
        f"nf {model.NF}",
        "fields " + ",".join(model.FIELDS),
        f"dt {model.DEFAULTS['dt']}",
    ]

    for tag, nz, nyp, nxp in PATCHES:
        path = os.path.join(outdir, f"model_{tag}.hlo.txt")
        text = lower_rank_step(nz, nyp, nxp)
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(f"model {tag} nz={nz} nyp={nyp} nxp={nxp} file=model_{tag}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")

    for nz, ny, nx in ANALYSIS_GRIDS:
        path = os.path.join(outdir, f"analysis_{ny}x{nx}.hlo.txt")
        text = lower_analysis(nz, ny, nx)
        with open(path, "w") as fh:
            fh.write(text)
        manifest.append(
            f"analysis nz={nz} ny={ny} nx={nx} file=analysis_{ny}x{nx}.hlo.txt"
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
