//! Build probe for the PJRT bindings (DESIGN.md §8).
//!
//! The `xla-runtime` *feature* is a behavior flag: it must build (and be
//! CI-tested) in environments without the `xla` binding crate, which is
//! not in the offline vendor set.  The real PJRT implementation is
//! therefore gated on `all(feature = "xla-runtime", xla_bindings)`, where
//! the `xla_bindings` cfg is emitted here only when the operator opts in
//! with `STORMIO_XLA_BINDINGS=1` *after* adding the `xla` crate to
//! `[dependencies]`.  Without it, the feature compiles against the same
//! stub as the default build, whose constructors explain what is missing.

fn main() {
    println!("cargo:rustc-check-cfg=cfg(xla_bindings)");
    println!("cargo:rerun-if-env-changed=STORMIO_XLA_BINDINGS");
    if std::env::var("STORMIO_XLA_BINDINGS").map(|v| v == "1").unwrap_or(false) {
        println!("cargo:rustc-cfg=xla_bindings");
    }
}
